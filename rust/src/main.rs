//! fedcompress — leader binary: CLI over the experiment drivers.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use fedcompress::baselines::StrategyRegistry;
use fedcompress::bench::diff::{diff_docs, DEFAULT_THRESHOLD_PCT};
use fedcompress::bench::schema::BenchDoc;
use fedcompress::bench::suite::{self, AREAS};
use fedcompress::cli::{Args, ParsedCommand, USAGE};
use fedcompress::clustering::ControllerConfig;
use fedcompress::codec::CodecRegistry;
use fedcompress::compression::accounting::ccr;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::checkpoint::Checkpoint;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::coordinator::{run_with_strategy_sink, RunResult};
use fedcompress::exp::{figure2, fleet, table1, table2};
use fedcompress::models::flops;
use fedcompress::net::{worker, InProcess, TcpServer, Transport};
use fedcompress::obs::sink::{EventSink, FileSink, NULL_SINK};
use fedcompress::obs::stream::{
    parse_stream, record_stream_events, StreamEvent, StreamHeader, StreamReplay,
};
use fedcompress::obs::view::{sweep_progress_line, RunView, SweepView};
use fedcompress::runtime::Engine;
use fedcompress::sim::FleetPreset;
use fedcompress::store::{
    diff_records, export, key_hex, parse_key_hex, run_key, RunRecord, RunStore,
};
use fedcompress::sweep::{run_sweep, EngineRunner, JobRunner, SmokeRunner, SweepEvent, SweepSpec};
use fedcompress::util::csv;
use fedcompress::util::logging;
use fedcompress::util::table;
use fedcompress::util::threadpool::default_workers;

fn build_config(args: &Args) -> Result<FedConfig> {
    let dataset = args.flag_or("dataset", "cifar10");
    let mut cfg = match args.flag_or("preset", "quick") {
        "paper" => FedConfig::paper(dataset),
        _ => FedConfig::quick(dataset),
    };
    if let Some(path) = args.flag("config") {
        cfg.load_overrides(Path::new(path))?;
    }
    // --dataset wins over a dataset inside --config
    if let Some(ds) = args.flag("dataset") {
        cfg.dataset = ds.to_string();
    }
    for (k, v) in &args.sets {
        cfg.set(k, v)?;
    }
    // codec pipeline override (sugar over --set codec=<spec>)
    if let Some(spec) = args.flag("codec") {
        cfg.set("codec", spec)?;
    }
    // fleet simulation flags (sugar over --set fleet=/dropout=/deadline_s=)
    if let Some(name) = args.flag("fleet") {
        cfg.set("fleet", name)?;
    }
    if let Some(p) = args.flag("dropout") {
        cfg.set("dropout", p)?;
    }
    if let Some(s) = args.flag("deadline-s") {
        cfg.set("deadline_s", s)?;
    }
    if let Some(n) = args.flag("edge-of") {
        cfg.set("edge_of", n)?;
    }
    // transport handshake guard (sugar over --set handshake_timeout_s=)
    if let Some(s) = args.flag("handshake-timeout-s") {
        cfg.set("handshake_timeout_s", s)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(fedcompress::runtime::artifacts::default_dir)
}

fn engine_for(args: &Args) -> Result<Engine> {
    Engine::load(&artifacts_dir(args))
}

/// `--store <dir>`: open the run store when the flag is present.
fn store_for(args: &Args) -> Result<Option<RunStore>> {
    match args.flag("store") {
        Some(dir) => Ok(Some(RunStore::open(Path::new(dir))?)),
        None => Ok(None),
    }
}

/// Print a header + rows as an aligned terminal table.
fn print_aligned(header: &[&str], rows: &[Vec<String>]) {
    print!("{}", table::render_right(header, rows));
}

/// Shared `--csv` / `--out` tail of the `runs` table subcommands.
fn emit_table(args: &Args, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let as_csv = args.flag("csv").is_some();
    match (args.flag("out"), as_csv) {
        (Some(path), _) => {
            csv::write_file(Path::new(path), header, rows)?;
            println!("wrote {path}");
        }
        (None, true) => print!("{}", csv::render(header, rows)),
        (None, false) => print_aligned(header, rows),
    }
    Ok(())
}

/// `--store <dir>` on train/serve: live-tee the run's event stream to
/// `<store>/events/<key>.jsonl` and persist the finished record.
struct RunTee {
    store: RunStore,
    key: u64,
    sink: FileSink,
}

/// The live sink a run should emit to: the tee's file sink, or the
/// null sink when `--store` was not given.
fn tee_sink(tee: &Option<RunTee>) -> &dyn EventSink {
    match tee {
        Some(t) => &t.sink,
        None => &NULL_SINK,
    }
}

fn open_run_tee(args: &Args, cfg: &FedConfig, strategy: &str) -> Result<Option<RunTee>> {
    let store = match store_for(args)? {
        Some(s) => s,
        None => return Ok(None),
    };
    let key = run_key(strategy, cfg);
    let path = store
        .dir()
        .join("events")
        .join(format!("{}.jsonl", key_hex(key)));
    let sink = FileSink::create(&path, &StreamHeader::new(key, cfg, strategy), 4096)?;
    println!("event stream: {}", sink.path().display());
    Ok(Some(RunTee { store, key, sink }))
}

/// Persist the finished run, close the stream, print the tail hint.
fn close_run_tee(tee: Option<RunTee>, cfg: &FedConfig, result: &RunResult) -> Result<()> {
    if let Some(RunTee { mut store, key, sink }) = tee {
        store.append(&RunRecord::from_result(cfg, result))?;
        store.flush_sidecar()?;
        let dropped = sink.finish()?;
        if dropped > 0 {
            println!("event stream: {dropped} event(s) dropped by the bounded sink");
        }
        println!(
            "run stored — replay with: fedcompress runs tail {} --store {}",
            key_hex(key),
            store.dir().display()
        );
    }
    Ok(())
}

/// `--resume ckpt`: load the checkpoint a run continues from.
fn load_resume(args: &Args) -> Result<Option<Checkpoint>> {
    match args.flag("resume") {
        Some(path) => Ok(Some(Checkpoint::load(Path::new(path))?)),
        None => Ok(None),
    }
}

/// Shared tail of `train`/`serve`: summary line, checkpoint stamped
/// with the run environment, event log.
fn finish_run(args: &Args, cfg: &FedConfig, result: &RunResult, transport: &str) -> Result<()> {
    println!(
        "\n[{}] {}: final acc={:.4} total_comm={} B (framed {} B) mcr={:.2} \
         (dense model {} B, wire {} B)",
        result.strategy,
        result.dataset,
        result.final_accuracy,
        result.total_bytes(),
        result.total_framed_bytes(),
        result.mcr(),
        result.dense_model_bytes,
        result.final_model_bytes,
    );
    // per-stage wire breakdown (codec pipelines ledger each stage)
    let stages = result.ledger.render_stage_totals();
    if !stages.is_empty() {
        println!("per-stage wire bytes: {stages}");
    }
    if !cfg.codec.is_empty() {
        println!("codec override: {}", cfg.codec);
    }
    // persist the final model + codebook as a resumable checkpoint
    if let Some(path) = args.flag("checkpoint") {
        let scores: Vec<f64> = result.rounds.iter().map(|r| r.score).collect();
        let ckpt = Checkpoint::from_state(
            cfg.rounds,
            &result.final_theta,
            &result.final_centroids,
            &scores,
            transport,
            cfg.fleet.preset.name(),
        );
        ckpt.save(Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    // structured event log (JSON lines) for observability tooling
    if let Some(path) = args.flag("events") {
        std::fs::write(path, result.events.to_jsonl())?;
        println!("event log ({} events) written to {path}", result.events.len());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let strategy = args.flag_or("strategy", "fedcompress");
    // `--strategy list` / `--codec list` print the registries without
    // needing artifacts
    if strategy == "list" {
        print!("{}", StrategyRegistry::builtin().render_list());
        return Ok(());
    }
    if args.flag("codec") == Some("list") {
        print!("{}", CodecRegistry::builtin().render_list());
        return Ok(());
    }
    let cfg = build_config(args)?;
    // resolve early so a typo fails with a suggestion before the
    // engine spins up
    let mut plugin = StrategyRegistry::builtin().build(strategy, &cfg)?;
    let engine = engine_for(args)?;
    let data = build_data(&engine, &cfg)?;
    let resume = load_resume(args)?;
    let tee = open_run_tee(args, &cfg, plugin.name())?;
    let mut transport = InProcess;
    let result = run_with_strategy_sink(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        resume.as_ref(),
        tee_sink(&tee),
    )?;
    close_run_tee(tee, &cfg, &result)?;
    finish_run(args, &cfg, &result, transport.kind().name())
}

/// The networked coordinator: wait for N workers, then run the same
/// round loop over framed TCP.
fn cmd_serve(args: &Args) -> Result<()> {
    let strategy = args.flag_or("strategy", "fedcompress");
    let cfg = build_config(args)?;
    let mut plugin = StrategyRegistry::builtin().build(strategy, &cfg)?;
    // fail on missing artifacts *before* holding a port open
    let engine = engine_for(args)?;
    let data = build_data(&engine, &cfg)?;
    let resume = load_resume(args)?;

    let bind = args.flag_or("bind", "127.0.0.1:7878");
    let workers: usize = args.flag_or("workers", "1").parse()?;
    let timeout_s: f64 = args.flag_or("timeout-s", "0").parse()?;
    anyhow::ensure!(timeout_s >= 0.0, "--timeout-s must be >= 0");
    let timeout = (timeout_s > 0.0).then(|| Duration::from_secs_f64(timeout_s));

    let server = TcpServer::bind(bind, workers, &cfg, strategy, timeout)?;
    println!(
        "coordinator listening on {} — waiting for {workers} worker(s) \
         (fedcompress worker --connect <addr>)",
        server.local_addr()?
    );
    let mut transport = server.accept_workers()?;
    let tee = open_run_tee(args, &cfg, plugin.name())?;
    let result = run_with_strategy_sink(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        resume.as_ref(),
        tee_sink(&tee),
    )?;
    transport.shutdown()?;
    println!(
        "control-plane traffic: {} B across handshake + round control \
         ({} of {} workers still alive)",
        transport.control_bytes(),
        transport.alive_workers(),
        workers
    );
    close_run_tee(tee, &cfg, &result)?;
    finish_run(args, &cfg, &result, "tcp")
}

/// One worker process; everything but the address, artifacts dir, and
/// an optional edge-aggregator capacity arrives at handshake.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .flag("connect")
        .context("worker needs --connect <addr>")?;
    let edge_of: usize = args.flag_or("edge-of", "0").parse()?;
    let uploads = worker::run_worker_opts(
        addr,
        &artifacts_dir(args),
        fedcompress::codec::CodecRegistry::builtin(),
        edge_of,
    )?;
    println!("worker finished cleanly after {uploads} uploads");
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let mut store = store_for(args)?;
    let list = args.flag_or(
        "datasets",
        "cifar10,cifar100,pathmnist,speechcommands,voxforge",
    );
    if let Some(banner) = fedcompress::exp::codec_banner(&build_config(args)?) {
        println!("{banner}");
    }
    table1::print_header();
    let mut rows = Vec::new();
    let mut stats = fedcompress::sweep::CacheStats::default();
    for ds in list.split(',').filter(|s| !s.is_empty()) {
        let mut sub = args.clone();
        sub.flags.insert("dataset".into(), ds.to_string());
        let cfg = build_config(&sub)?;
        let (row, ds_stats) = table1::run_dataset_cached(&engine, &cfg, store.as_mut())?;
        stats.hits += ds_stats.hits;
        stats.misses += ds_stats.misses;
        table1::print_row(&row);
        rows.push(row);
    }
    println!();
    table1::print_summary(&rows);
    if store.is_some() {
        println!(
            "run store: {} cache hit(s), {} executed",
            stats.hits, stats.misses
        );
    }
    if let Some(out) = args.flag("out") {
        table1::write_csv(&rows, Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    // the deployed cluster count: either a flag, or read from a stored
    // run's final round (the controller's real landing point)
    let c: usize = match args.flag("from-run") {
        Some(hex) => {
            let store = RunStore::open(Path::new(args.flag_or("store", "runs")))?;
            let key = store.resolve(hex)?;
            let rec = store.get(key)?.expect("resolved key exists");
            let c = rec
                .final_clusters()
                .context("stored run has no rounds to read a cluster count from")?;
            println!(
                "deployed C={c} from run {} ({} on {})\n",
                key_hex(key),
                rec.strategy,
                rec.cfg().map(|c| c.dataset).unwrap_or_default()
            );
            c
        }
        None => args.flag_or("clusters", "16").parse()?,
    };
    let mut all_rows = Vec::new();
    for model in ["resnet20", "mobilenet"] {
        let rows = table2::run(model, c)?;
        table2::print_rows(&rows);
        println!();
        all_rows.extend(rows);
    }
    if let Some(out) = args.flag("out") {
        table2::write_csv(&all_rows, Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Fleet scenario table: every registered strategy under the named
/// fleet presets (all three by default, or just `--fleet <name>`).
fn cmd_fleet(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let mut store = store_for(args)?;
    let cfg = build_config(args)?;
    let presets: Vec<FleetPreset> = match args.flag("fleet") {
        Some(name) => vec![FleetPreset::from_name(name)?],
        None => FleetPreset::ALL.to_vec(),
    };
    if let Some(banner) = fedcompress::exp::codec_banner(&cfg) {
        println!("{banner}");
    }
    let (table, stats) = fleet::run_cached(&engine, &cfg, &presets, store.as_mut())?;
    fleet::print_table(&table);
    if store.is_some() {
        println!(
            "run store: {} cache hit(s), {} executed",
            stats.hits, stats.misses
        );
    }
    Ok(())
}

/// Expand the sweep grid and run it against the store.
fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let mut spec = match args.flag("spec") {
        Some(path) => SweepSpec::from_file(Path::new(path))?,
        None => SweepSpec::default(),
    };
    if let Some(list) = args.flag("strategies") {
        spec.strategies
            .extend(list.split(',').filter(|s| !s.is_empty()).map(String::from));
    }
    if let Some(list) = args.flag("fleets") {
        spec.fleets.extend(FleetPreset::parse_list(list)?);
    }
    if let Some(list) = args.flag("seeds") {
        for s in list.split(',').filter(|s| !s.is_empty()) {
            spec.seeds
                .push(s.parse().with_context(|| format!("--seeds value '{s}'"))?);
        }
    }
    for (k, v) in &args.axes {
        spec.push_axis(k, v)?;
    }

    let registry = StrategyRegistry::builtin();
    let jobs = spec.expand(&cfg, &registry)?;
    let mut store = RunStore::open(Path::new(args.flag_or("store", "runs")))?;
    let workers: usize = match args.flag("jobs") {
        Some(j) => j.parse()?,
        None => default_workers(),
    };

    let engine_runner;
    let runner: &dyn JobRunner = if args.flag("smoke").is_some() {
        &SmokeRunner
    } else {
        // fail on missing artifacts up front (cheap existence probe —
        // the workers each load their own engine anyway)
        let dir = artifacts_dir(args);
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "no AOT artifacts at {dir:?} — build them with python/compile/aot.py, \
             or use --smoke for the synthetic runner"
        );
        engine_runner = EngineRunner { artifacts_dir: dir };
        &engine_runner
    };

    let total = jobs.len();
    // every SweepEvent is teed to <store>/events/sweep.jsonl as a
    // first-class stream event; per-job run streams land next to it
    let events_dir = store.dir().join("events");
    let sweep_sink = FileSink::create(
        &events_dir.join("sweep.jsonl"),
        &StreamHeader::new(0, &cfg, "sweep"),
        4096,
    )?;
    let watch = args.flag("watch").is_some();
    let view = std::sync::Mutex::new(SweepView::new());
    let progress = |e: SweepEvent| {
        let ev = StreamEvent::from(&e);
        sweep_sink.emit(&ev);
        if watch {
            // full-screen refresh: clear, home, re-render the table
            let mut v = view.lock().unwrap();
            v.apply(&ev);
            print!("\x1b[2J\x1b[H{}", v.render());
            use std::io::Write;
            let _ = std::io::stdout().flush();
        } else {
            println!("{}", sweep_progress_line(&e, total, workers));
        }
    };
    let force = args.flag("force").is_some();
    let outcome = run_sweep(
        &jobs,
        &mut store,
        runner,
        workers,
        force,
        Some(&events_dir),
        &progress,
    )?;
    let stream_drops = sweep_sink.finish()?;
    if stream_drops > 0 {
        println!("event stream: {stream_drops} event(s) dropped by the bounded sink");
    }
    println!("{}", outcome.summary());
    println!("store: {} record(s) at {:?}", store.len(), store.dir());
    anyhow::ensure!(outcome.failed == 0, "{} sweep job(s) failed", outcome.failed);
    Ok(())
}

/// `runs <sub>` — query the store.
fn cmd_runs(args: &Args) -> Result<()> {
    let store = RunStore::open(Path::new(args.flag_or("store", "runs")))?;
    match args.sub.as_deref().unwrap_or("list") {
        "list" => {
            let latest = store.latest();
            emit_table(args, &export::LIST_HEADER, &export::list_rows(&latest))?;
            println!(
                "{} record(s), {} entr(ies) on disk at {:?}",
                store.len(),
                store.metas().len(),
                store.dir()
            );
        }
        "show" => {
            let hex = args.flag("key").context("runs show needs --key <hex>")?;
            let key = store.resolve(hex)?;
            let rec = store.get(key)?.expect("resolved key exists");
            let cfg = rec.cfg()?;
            println!(
                "run {}: {} on {} (fleet={}, codec={}, seed={})",
                key_hex(key),
                rec.strategy,
                cfg.dataset,
                cfg.fleet.preset.name(),
                if cfg.codec.is_empty() { "-" } else { &cfg.codec },
                cfg.seed
            );
            let parsed = rec.events();
            let bad = match parsed.errors.len() {
                0 => String::new(),
                n => format!(" ({n} bad line(s))"),
            };
            println!(
                "final acc={:.4} model={} B (dense {} B, mcr={:.2}) comm={} B \
                 (framed {} B) sim={:.1}s events={}{}",
                rec.final_accuracy,
                rec.final_model_bytes,
                rec.dense_model_bytes,
                rec.mcr(),
                rec.total_bytes(),
                rec.total_framed_bytes(),
                rec.total_sim_ms() / 1e3,
                parsed.log.len(),
                bad
            );
            emit_table(args, &export::ROUNDS_HEADER, &export::rounds_rows(&rec))?;
        }
        "tail" => return cmd_runs_tail(args, &store),
        "diff" => return cmd_runs_diff(args, &store),
        "compare" => {
            let latest = store.latest();
            emit_table(args, &export::COMPARE_HEADER, &export::compare_rows(&latest))?;
        }
        "export-bench" => {
            let out = args.flag_or("out", "BENCH_sweep.json");
            export::write_bench_json(&store, Path::new(out))?;
            println!("wrote {out} ({} record(s))", store.len());
        }
        other => anyhow::bail!(
            "unknown runs subcommand '{other}' (list|show|tail|diff|compare|export-bench)"
        ),
    }
    Ok(())
}

/// `runs tail <key> [--follow]`: render the run view — from the teed
/// stream file when one exists (it carries the ops-only detail), else
/// replayed from the stored record. `--follow` refreshes the screen
/// until interrupted, so a live `train --store` run can be tailed from
/// another terminal.
fn cmd_runs_tail(args: &Args, store: &RunStore) -> Result<()> {
    let hex = match args.flag("key") {
        Some(h) => h,
        None => args
            .positionals
            .first()
            .map(|s| s.as_str())
            .context("runs tail needs a <key> positional or --key <hex>")?,
    };
    let key = match store.resolve(hex) {
        Ok(k) => k,
        // a run being teed right now is not in the index yet; a full
        // 16-hex key still addresses its stream file directly
        Err(e) => parse_key_hex(hex).map_err(|_| e)?,
    };
    let stream_path = store
        .dir()
        .join("events")
        .join(format!("{}.jsonl", key_hex(key)));
    let follow = args.flag("follow").is_some();
    loop {
        let replay = load_replay(store, key, &stream_path)?;
        let view = RunView::from_replay(&replay);
        if !follow {
            print!("{}", view.render());
            return Ok(());
        }
        print!("\x1b[2J\x1b[H{}", view.render());
        use std::io::Write;
        let _ = std::io::stdout().flush();
        std::thread::sleep(Duration::from_millis(700));
    }
}

/// The replay source for `runs tail`: the stream file if readable,
/// otherwise a stream synthesized from the stored record.
fn load_replay(store: &RunStore, key: u64, stream_path: &Path) -> Result<StreamReplay> {
    if let Ok(text) = std::fs::read_to_string(stream_path) {
        return Ok(parse_stream(&text));
    }
    let rec = store.get(key)?.context("key resolved but record missing")?;
    let (events, errors) = record_stream_events(&rec);
    Ok(StreamReplay {
        header: Some(StreamHeader::for_record(&rec)),
        events,
        errors,
    })
}

/// `runs diff`: bit-exact drift check — two records (`--a`/`--b`) or
/// every shared key of two stores (`--other <dir>`). Exits non-zero on
/// any drift, so scripts can assert reproducibility.
fn cmd_runs_diff(args: &Args, store: &RunStore) -> Result<()> {
    if let Some(other_dir) = args.flag("other") {
        let other = RunStore::open(Path::new(other_dir))?;
        let mut shared = 0usize;
        let mut drifted = 0usize;
        for key in store.keys() {
            let Some(theirs) = other.get(key)? else {
                continue;
            };
            shared += 1;
            let ours = store.get(key)?.expect("listed key exists");
            let d = diff_records(&ours, &theirs);
            if !d.is_identical() {
                drifted += 1;
                println!("{}: drift in {}", key_hex(key), d.fields.join(", "));
            }
        }
        let only_ours = store.keys().iter().filter(|k| !other.contains(**k)).count();
        let only_theirs = other.keys().iter().filter(|k| !store.contains(**k)).count();
        println!(
            "compared {shared} shared key(s): {drifted} drifted \
             ({only_ours} only here, {only_theirs} only in {other_dir})"
        );
        anyhow::ensure!(drifted == 0, "{drifted} record(s) drifted");
        return Ok(());
    }
    let need = "runs diff needs --a <hex> --b <hex> or --other <dir>";
    let a_hex = args.flag("a").context(need)?;
    let b_hex = args.flag("b").context(need)?;
    let a = store.get(store.resolve(a_hex)?)?.expect("resolved key exists");
    let b = store.get(store.resolve(b_hex)?)?.expect("resolved key exists");
    let d = diff_records(&a, &b);
    if d.is_identical() {
        println!(
            "records {} and {} are bit-identical (metrics, ledger, events)",
            key_hex(a.key),
            key_hex(b.key)
        );
        Ok(())
    } else {
        for f in &d.fields {
            println!("drift: {f}");
        }
        anyhow::bail!("{} field(s) drifted", d.fields.len())
    }
}

fn cmd_figure2(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let cfg = build_config(args)?;
    let series = figure2::run(&engine, &cfg)?;
    figure2::print_series(&series);
    if let Some(out) = args.flag("out") {
        figure2::write_csv(&series, Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Ablation A2: dynamic controller vs fixed C — accuracy/CCR trade.
fn cmd_ablate_c(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let base_cfg = build_config(args)?;

    println!(
        "{:<22} {:>9} {:>8} {:>8} {:>8}",
        "variant", "final_acc", "CCR", "MCR", "final_C"
    );
    let data = build_data(&engine, &base_cfg)?;
    let fedavg = run_federated_with_data(&engine, &base_cfg, "fedavg", &data)?;

    // dynamic (the paper's controller)
    let dynamic = run_federated_with_data(&engine, &base_cfg, "fedcompress", &data)?;
    println!(
        "{:<22} {:>9.4} {:>8.2} {:>8.2} {:>8}",
        "dynamic [Cmin,Cmax]",
        dynamic.final_accuracy,
        ccr(&fedavg.ledger, &dynamic.ledger),
        dynamic.mcr(),
        dynamic.rounds.last().map(|r| r.clusters).unwrap_or(0)
    );

    // fixed C variants: controller pinned (c_min == c_max)
    for c in [8usize, 16, 32] {
        let mut cfg = base_cfg.clone();
        cfg.controller = ControllerConfig {
            c_min: c,
            c_max: c,
            ..base_cfg.controller.clone()
        };
        let r = run_federated_with_data(&engine, &cfg, "fedcompress", &data)?;
        println!(
            "{:<22} {:>9.4} {:>8.2} {:>8.2} {:>8}",
            format!("fixed C={c}"),
            r.final_accuracy,
            ccr(&fedavg.ledger, &r.ledger),
            r.mcr(),
            c
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    println!(
        "artifacts: {} datasets, C_max={}, batch={}, tau={}",
        engine.manifest.datasets.len(),
        engine.manifest.c_max,
        engine.manifest.batch,
        engine.manifest.tau
    );
    for (name, ds) in &engine.manifest.datasets {
        let spec = &ds.spec;
        println!(
            "  {name:<16} {:>7} params  {} classes  {:?}  {:.1} MFLOPs/inference",
            spec.param_count,
            spec.num_classes,
            spec.input_shape,
            flops::total_flops(spec) as f64 / 1e6,
        );
        for entry in ds.artifacts.keys() {
            let sig = &ds.signatures[entry];
            println!(
                "      {entry:<14} {} inputs -> {} outputs",
                sig.inputs.len(),
                sig.output_shapes.len()
            );
        }
    }
    Ok(())
}

/// Exit code for a `bench diff` perf regression — distinct from `1`
/// (schema/usage error) so CI can soft-fail regressions on noisy
/// runners while hard-failing malformed baselines.
const BENCH_REGRESSION_EXIT: i32 = 3;

fn cmd_bench(args: &Args) -> Result<()> {
    match args.sub.as_deref() {
        Some("run") => cmd_bench_run(args),
        Some("diff") => cmd_bench_diff(args),
        other => anyhow::bail!(
            "unknown bench subcommand '{}' (run|diff)",
            other.unwrap_or("<none>")
        ),
    }
}

/// `bench run [--area <name>|all|rounds] [--quick] [--out-dir d]
/// [--store dir]`: run the in-process suites headlessly and write one
/// `BENCH_<area>.json` per area (the committed perf-trajectory
/// baselines come from exactly this path).
fn cmd_bench_run(args: &Args) -> Result<()> {
    args.restrict(&["area", "quick", "out-dir", "store", "verbose"])?;
    anyhow::ensure!(
        args.positionals.is_empty(),
        "bench run takes no positionals (areas go through --area)"
    );
    let quick = args.flag("quick").is_some();
    let out_dir = PathBuf::from(args.flag_or("out-dir", "."));
    let names: Vec<&str> = match args.flag_or("area", "all") {
        // `rounds` is store-derived, not a suite — only explicit
        "all" => AREAS.iter().map(|a| a.name).collect(),
        one => vec![one],
    };
    for name in names {
        let doc = if name == "rounds" {
            let store = Path::new(args.flag_or("store", "runs"));
            suite::rounds_rollup(&store.join("events"), quick)?
        } else {
            suite::run_area(name, quick)?
        };
        let out = out_dir.join(format!("BENCH_{name}.json"));
        doc.write(&out)?;
        println!(
            "bench: wrote {} ({} row(s), quick={quick})",
            out.display(),
            doc.rows.len()
        );
    }
    Ok(())
}

/// `bench diff <old.json> <new.json> [--threshold-pct N] [--json]`:
/// name-wise median comparison. Exit 0 when clean (missing/added rows
/// and incomparable medians are reported, never failed), exit
/// [`BENCH_REGRESSION_EXIT`] when any row regressed past the
/// threshold; schema errors exit 1 through the normal error path.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    args.restrict(&["threshold-pct", "json", "verbose"])?;
    anyhow::ensure!(
        args.positionals.len() == 2,
        "bench diff needs exactly two positionals: <old.json> <new.json>"
    );
    let threshold = match args.flag("threshold-pct") {
        Some(t) => {
            let v: f64 = t
                .parse()
                .with_context(|| format!("parsing --threshold-pct '{t}'"))?;
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "--threshold-pct must be a finite non-negative percentage, got {t}"
            );
            v
        }
        None => DEFAULT_THRESHOLD_PCT,
    };
    let old = BenchDoc::load(Path::new(&args.positionals[0]))?;
    let new = BenchDoc::load(Path::new(&args.positionals[1]))?;
    let d = diff_docs(&old, &new, threshold);
    if args.flag("json").is_some() {
        println!("{}", d.to_json());
    } else {
        print!("{}", d.render());
    }
    if d.regressions() > 0 {
        std::process::exit(BENCH_REGRESSION_EXIT);
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use fedcompress::lint::{self, LintConfig};

    args.restrict(&["json", "rule", "root", "out", "config", "verbose"])?;
    // Auto-detect the crate root: run from rust/ or from the repo root.
    let root = match args.flag("root") {
        Some(r) => PathBuf::from(r),
        None if Path::new("src/lib.rs").exists() => PathBuf::from("."),
        None if Path::new("rust/src/lib.rs").exists() => PathBuf::from("rust"),
        None => anyhow::bail!(
            "cannot find the crate root (no src/lib.rs here or under rust/); pass --root"
        ),
    };
    let cfg = match args.flag("config") {
        Some(f) => LintConfig::from_file(Path::new(f)).map_err(anyhow::Error::msg)?,
        None => {
            let committed = root.join("fedlint.toml");
            if committed.exists() {
                LintConfig::from_file(&committed).map_err(anyhow::Error::msg)?
            } else {
                LintConfig::builtin()
            }
        }
    };
    let report = lint::lint_root(&root, &cfg, args.flag("rule"), &args.positionals)
        .map_err(anyhow::Error::msg)?;
    let json = lint::render_json(&report);
    if let Some(out) = args.flag("out") {
        std::fs::write(out, format!("{json}\n")).with_context(|| format!("writing {out}"))?;
    }
    if args.flag("json").is_some() {
        println!("{json}");
    } else {
        print!("{}", lint::render_text(&report));
    }
    anyhow::ensure!(
        report.deny_count() == 0,
        "fedlint: {} deny-severity violation(s)",
        report.deny_count()
    );
    Ok(())
}

fn main() -> Result<()> {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.command()? {
        ParsedCommand::Help => {
            println!("{USAGE}");
            Ok(())
        }
        ParsedCommand::Train => cmd_train(&args),
        ParsedCommand::Serve => cmd_serve(&args),
        ParsedCommand::Worker => cmd_worker(&args),
        ParsedCommand::Table1 => cmd_table1(&args),
        ParsedCommand::Table2 => cmd_table2(&args),
        ParsedCommand::Figure2 => cmd_figure2(&args),
        ParsedCommand::Fleet => cmd_fleet(&args),
        ParsedCommand::Sweep => cmd_sweep(&args),
        ParsedCommand::Runs => cmd_runs(&args),
        ParsedCommand::Bench => cmd_bench(&args),
        ParsedCommand::Lint => cmd_lint(&args),
        ParsedCommand::AblateC => cmd_ablate_c(&args),
        ParsedCommand::Inspect => cmd_inspect(&args),
    }
}
