//! fedcompress — leader binary: CLI over the experiment drivers.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use fedcompress::baselines::StrategyRegistry;
use fedcompress::cli::{Args, ParsedCommand, USAGE};
use fedcompress::clustering::ControllerConfig;
use fedcompress::compression::accounting::ccr;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::checkpoint::Checkpoint;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::coordinator::{run_with_strategy_opts, RunResult};
use fedcompress::exp::{figure2, fleet, table1, table2};
use fedcompress::models::flops;
use fedcompress::net::{worker, InProcess, TcpServer, Transport};
use fedcompress::runtime::Engine;
use fedcompress::sim::FleetPreset;
use fedcompress::util::logging;

fn build_config(args: &Args) -> Result<FedConfig> {
    let dataset = args.flag_or("dataset", "cifar10");
    let mut cfg = match args.flag_or("preset", "quick") {
        "paper" => FedConfig::paper(dataset),
        _ => FedConfig::quick(dataset),
    };
    if let Some(path) = args.flag("config") {
        cfg.load_overrides(Path::new(path))?;
    }
    // --dataset wins over a dataset inside --config
    if let Some(ds) = args.flag("dataset") {
        cfg.dataset = ds.to_string();
    }
    for (k, v) in &args.sets {
        cfg.set(k, v)?;
    }
    // fleet simulation flags (sugar over --set fleet=/dropout=/deadline_s=)
    if let Some(name) = args.flag("fleet") {
        cfg.set("fleet", name)?;
    }
    if let Some(p) = args.flag("dropout") {
        cfg.set("dropout", p)?;
    }
    if let Some(s) = args.flag("deadline-s") {
        cfg.set("deadline_s", s)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn engine_for(args: &Args) -> Result<Engine> {
    let dir = args
        .flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(fedcompress::runtime::artifacts::default_dir);
    Engine::load(&dir)
}

/// `--resume ckpt`: load the checkpoint a run continues from.
fn load_resume(args: &Args) -> Result<Option<Checkpoint>> {
    match args.flag("resume") {
        Some(path) => Ok(Some(Checkpoint::load(Path::new(path))?)),
        None => Ok(None),
    }
}

/// Shared tail of `train`/`serve`: summary line, checkpoint stamped
/// with the run environment, event log.
fn finish_run(args: &Args, cfg: &FedConfig, result: &RunResult, transport: &str) -> Result<()> {
    println!(
        "\n[{}] {}: final acc={:.4} total_comm={} B (framed {} B) mcr={:.2} \
         (dense model {} B, wire {} B)",
        result.strategy,
        result.dataset,
        result.final_accuracy,
        result.total_bytes(),
        result.total_framed_bytes(),
        result.mcr(),
        result.dense_model_bytes,
        result.final_model_bytes,
    );
    // persist the final model + codebook as a resumable checkpoint
    if let Some(path) = args.flag("checkpoint") {
        let scores: Vec<f64> = result.rounds.iter().map(|r| r.score).collect();
        let ckpt = Checkpoint::from_state(
            cfg.rounds,
            &result.final_theta,
            &result.final_centroids,
            &scores,
            transport,
            cfg.fleet.preset.name(),
        );
        ckpt.save(Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    // structured event log (JSON lines) for observability tooling
    if let Some(path) = args.flag("events") {
        std::fs::write(path, result.events.to_jsonl())?;
        println!("event log ({} events) written to {path}", result.events.len());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let strategy = args.flag_or("strategy", "fedcompress");
    // `--strategy list` prints the registry without needing artifacts
    if strategy == "list" {
        print!("{}", StrategyRegistry::builtin().render_list());
        return Ok(());
    }
    let cfg = build_config(args)?;
    // resolve early so a typo fails with a suggestion before the
    // engine spins up
    let mut plugin = StrategyRegistry::builtin().build(strategy, &cfg)?;
    let engine = engine_for(args)?;
    let data = build_data(&engine, &cfg)?;
    let resume = load_resume(args)?;
    let mut transport = InProcess;
    let result = run_with_strategy_opts(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        resume.as_ref(),
    )?;
    finish_run(args, &cfg, &result, transport.kind().name())
}

/// The networked coordinator: wait for N workers, then run the same
/// round loop over framed TCP.
fn cmd_serve(args: &Args) -> Result<()> {
    let strategy = args.flag_or("strategy", "fedcompress");
    let cfg = build_config(args)?;
    let mut plugin = StrategyRegistry::builtin().build(strategy, &cfg)?;
    // fail on missing artifacts *before* holding a port open
    let engine = engine_for(args)?;
    let data = build_data(&engine, &cfg)?;
    let resume = load_resume(args)?;

    let bind = args.flag_or("bind", "127.0.0.1:7878");
    let workers: usize = args.flag_or("workers", "1").parse()?;
    let timeout_s: f64 = args.flag_or("timeout-s", "0").parse()?;
    anyhow::ensure!(timeout_s >= 0.0, "--timeout-s must be >= 0");
    let timeout = (timeout_s > 0.0).then(|| Duration::from_secs_f64(timeout_s));

    let server = TcpServer::bind(bind, workers, &cfg, strategy, timeout)?;
    println!(
        "coordinator listening on {} — waiting for {workers} worker(s) \
         (fedcompress worker --connect <addr>)",
        server.local_addr()?
    );
    let mut transport = server.accept_workers()?;
    let result = run_with_strategy_opts(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        resume.as_ref(),
    )?;
    transport.shutdown()?;
    println!(
        "control-plane traffic: {} B across handshake + round control \
         ({} of {} workers still alive)",
        transport.control_bytes(),
        transport.alive_workers(),
        workers
    );
    finish_run(args, &cfg, &result, "tcp")
}

/// One worker process; everything but the address and artifacts dir
/// arrives at handshake.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .flag("connect")
        .context("worker needs --connect <addr>")?;
    let dir = args
        .flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(fedcompress::runtime::artifacts::default_dir);
    let uploads = worker::run_worker(addr, &dir)?;
    println!("worker finished cleanly after {uploads} uploads");
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let list = args.flag_or(
        "datasets",
        "cifar10,cifar100,pathmnist,speechcommands,voxforge",
    );
    table1::print_header();
    let mut rows = Vec::new();
    for ds in list.split(',').filter(|s| !s.is_empty()) {
        let mut sub = args.clone();
        sub.flags.insert("dataset".into(), ds.to_string());
        let cfg = build_config(&sub)?;
        let row = table1::run_dataset(&engine, &cfg)?;
        table1::print_row(&row);
        rows.push(row);
    }
    println!();
    table1::print_summary(&rows);
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let c: usize = args.flag_or("clusters", "16").parse()?;
    for model in ["resnet20", "mobilenet"] {
        let rows = table2::run(model, c)?;
        table2::print_rows(&rows);
        println!();
    }
    Ok(())
}

/// Fleet scenario table: every registered strategy under the named
/// fleet presets (all three by default, or just `--fleet <name>`).
fn cmd_fleet(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let cfg = build_config(args)?;
    let presets: Vec<FleetPreset> = match args.flag("fleet") {
        Some(name) => vec![FleetPreset::from_name(name)?],
        None => FleetPreset::ALL.to_vec(),
    };
    let table = fleet::run(&engine, &cfg, &presets)?;
    fleet::print_table(&table);
    Ok(())
}

fn cmd_figure2(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let cfg = build_config(args)?;
    let series = figure2::run(&engine, &cfg)?;
    figure2::print_series(&series);
    if let Some(out) = args.flag("out") {
        figure2::write_csv(&series, Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Ablation A2: dynamic controller vs fixed C — accuracy/CCR trade.
fn cmd_ablate_c(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    let base_cfg = build_config(args)?;

    println!(
        "{:<22} {:>9} {:>8} {:>8} {:>8}",
        "variant", "final_acc", "CCR", "MCR", "final_C"
    );
    let data = build_data(&engine, &base_cfg)?;
    let fedavg = run_federated_with_data(&engine, &base_cfg, "fedavg", &data)?;

    // dynamic (the paper's controller)
    let dynamic = run_federated_with_data(&engine, &base_cfg, "fedcompress", &data)?;
    println!(
        "{:<22} {:>9.4} {:>8.2} {:>8.2} {:>8}",
        "dynamic [Cmin,Cmax]",
        dynamic.final_accuracy,
        ccr(&fedavg.ledger, &dynamic.ledger),
        dynamic.mcr(),
        dynamic.rounds.last().map(|r| r.clusters).unwrap_or(0)
    );

    // fixed C variants: controller pinned (c_min == c_max)
    for c in [8usize, 16, 32] {
        let mut cfg = base_cfg.clone();
        cfg.controller = ControllerConfig {
            c_min: c,
            c_max: c,
            ..base_cfg.controller.clone()
        };
        let r = run_federated_with_data(&engine, &cfg, "fedcompress", &data)?;
        println!(
            "{:<22} {:>9.4} {:>8.2} {:>8.2} {:>8}",
            format!("fixed C={c}"),
            r.final_accuracy,
            ccr(&fedavg.ledger, &r.ledger),
            r.mcr(),
            c
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let engine = engine_for(args)?;
    println!(
        "artifacts: {} datasets, C_max={}, batch={}, tau={}",
        engine.manifest.datasets.len(),
        engine.manifest.c_max,
        engine.manifest.batch,
        engine.manifest.tau
    );
    for (name, ds) in &engine.manifest.datasets {
        let spec = &ds.spec;
        println!(
            "  {name:<16} {:>7} params  {} classes  {:?}  {:.1} MFLOPs/inference",
            spec.param_count,
            spec.num_classes,
            spec.input_shape,
            flops::total_flops(spec) as f64 / 1e6,
        );
        for entry in ds.artifacts.keys() {
            let sig = &ds.signatures[entry];
            println!(
                "      {entry:<14} {} inputs -> {} outputs",
                sig.inputs.len(),
                sig.output_shapes.len()
            );
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.command()? {
        ParsedCommand::Help => {
            println!("{USAGE}");
            Ok(())
        }
        ParsedCommand::Train => cmd_train(&args),
        ParsedCommand::Serve => cmd_serve(&args),
        ParsedCommand::Worker => cmd_worker(&args),
        ParsedCommand::Table1 => cmd_table1(&args),
        ParsedCommand::Table2 => cmd_table2(&args),
        ParsedCommand::Figure2 => cmd_figure2(&args),
        ParsedCommand::Fleet => cmd_fleet(&args),
        ParsedCommand::AblateC => cmd_ablate_c(&args),
        ParsedCommand::Inspect => cmd_inspect(&args),
    }
}
