//! Paper-scale model specs for the Table 2 edge analysis.
//!
//! Table 2 benchmarks the paper's actual deployment models — ResNet-20
//! (~0.27M params, CIFAR-10 32x32) and MobileNet (~4.2M params, audio
//! spectrograms) — not our training-testbed scale-downs. The federated
//! pipeline trains the lite models; the edge analysis evaluates the
//! latency consequences of the *same compression format* at deployment
//! scale, which is what the paper measures on Pixel 6 / Jetson / Coral.

use crate::models::{LayerEntry, LayerKind, ModelSpec};

fn conv(
    name: &str,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    groups: usize,
    off: &mut usize,
) -> Vec<LayerEntry> {
    let wsize = cout * (cin / groups) * k * k;
    let w = LayerEntry {
        layer: name.to_string(),
        kind: LayerKind::Conv,
        field: "w".into(),
        shape: vec![cout, cin / groups, k, k],
        offset: *off,
        size: wsize,
        stride,
        groups,
    };
    *off += wsize;
    let b = LayerEntry {
        layer: name.to_string(),
        kind: LayerKind::Conv,
        field: "b".into(),
        shape: vec![cout],
        offset: *off,
        size: cout,
        stride,
        groups,
    };
    *off += cout;
    vec![w, b]
}

fn dense(name: &str, din: usize, dout: usize, off: &mut usize) -> Vec<LayerEntry> {
    let w = LayerEntry {
        layer: name.to_string(),
        kind: LayerKind::Dense,
        field: "w".into(),
        shape: vec![din, dout],
        offset: *off,
        size: din * dout,
        stride: 1,
        groups: 1,
    };
    *off += din * dout;
    let b = LayerEntry {
        layer: name.to_string(),
        kind: LayerKind::Dense,
        field: "b".into(),
        shape: vec![dout],
        offset: *off,
        size: dout,
        stride: 1,
        groups: 1,
    };
    *off += dout;
    vec![w, b]
}

/// ResNet-20 for CIFAR (He 2016): 3 stages x 3 basic blocks at widths
/// 16/32/64, ~0.27M parameters.
pub fn resnet20() -> ModelSpec {
    let mut off = 0usize;
    let mut layers = Vec::new();
    layers.extend(conv("stem", 3, 16, 3, 1, 1, &mut off));
    let widths = [(16usize, 16usize), (16, 32), (32, 64)];
    for (s, &(cin, cout)) in widths.iter().enumerate() {
        for b in 0..3 {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let c_in = if b == 0 { cin } else { cout };
            layers.extend(conv(&format!("s{s}b{b}.conv1"), c_in, cout, 3, stride, 1, &mut off));
            layers.extend(conv(&format!("s{s}b{b}.conv2"), cout, cout, 3, 1, 1, &mut off));
            if stride == 2 || c_in != cout {
                layers.extend(conv(&format!("s{s}b{b}.skip"), c_in, cout, 1, stride, 1, &mut off));
            }
        }
    }
    layers.extend(dense("fc", 64, 10, &mut off));
    ModelSpec {
        name: "resnet20".into(),
        domain: "vision".into(),
        num_classes: 10,
        input_shape: (3, 32, 32),
        emb_dim: 64,
        param_count: off,
        layers,
    }
}

/// MobileNet v1 (Howard 2017) at width 1.0 over spectrogram input,
/// ~4.2M parameters (13 dw-separable blocks, 32 -> 1024 channels).
pub fn mobilenet() -> ModelSpec {
    let mut off = 0usize;
    let mut layers = Vec::new();
    layers.extend(conv("stem", 1, 32, 3, 2, 1, &mut off));
    // (cin, cout, stride) per dw-separable block
    let blocks: [(usize, usize, usize); 13] = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    for (i, &(cin, cout, stride)) in blocks.iter().enumerate() {
        layers.extend(conv(&format!("b{i}.dw"), cin, cin, 3, stride, cin, &mut off));
        layers.extend(conv(&format!("b{i}.pw"), cin, cout, 1, 1, 1, &mut off));
    }
    layers.extend(dense("fc", 1024, 12, &mut off));
    ModelSpec {
        name: "mobilenet".into(),
        domain: "audio".into(),
        num_classes: 12,
        input_shape: (1, 96, 64),
        emb_dim: 1024,
        param_count: off,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::flops::total_flops;

    #[test]
    fn resnet20_param_count_matches_paper() {
        let s = resnet20();
        s.validate().unwrap();
        assert!(
            (250_000..300_000).contains(&s.param_count),
            "{}",
            s.param_count
        );
        // ~41M MACs on 32x32 -> ~80 MFLOPs
        let f = total_flops(&s);
        assert!((60e6..120e6).contains(&(f as f64)), "{f}");
    }

    #[test]
    fn mobilenet_param_count_matches_paper() {
        let s = mobilenet();
        s.validate().unwrap();
        assert!(
            (2_800_000..4_800_000).contains(&s.param_count),
            "{}",
            s.param_count
        );
    }
}
