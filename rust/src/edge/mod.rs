//! Edge-device inference latency model — the Table 2 substrate.
//!
//! No Pixel 6 / Jetson Nano / Coral TPU exists in this environment, so
//! per DESIGN.md §3 we model what the paper measured: per-layer roofline
//! latency `max(flops/peak, bytes/bandwidth) + dispatch overhead`, where
//! clustered models shrink the *weight-streaming* term (codebook-indexed
//! weights: ceil(log2 C) bits/weight + a VMEM/cache-resident codebook)
//! and uint8 quantization shrinks both terms on integer-capable units.
//! Device constants come from public spec sheets; Table 2 reports
//! *ratios*, which are robust to the absolute calibration.

pub mod device;
pub mod latency;
pub mod paper_models;
pub mod quantize;

pub use device::{DeviceProfile, EDGE_DEVICES};
pub use latency::{inference_latency, speedup, Precision, WeightFormat};
