//! Per-layer latency under dense/clustered weights and f32/uint8
//! precision — the Table 2 substrate.
//!
//! Batch-1 mobile inference model (additive, no compute/DMA overlap —
//! the regime TFLite-class runtimes sit in on these devices):
//!
//! ```text
//! t_layer = flops/rate + weight_bytes/bw_weights
//!           + activation_bytes/bw_stream + overhead
//! ```
//!
//! `bw_weights` is the *effective strided-fetch* bandwidth for GEMM
//! weight tiles (a small fraction of peak DRAM bandwidth — weights are
//! walked in blocked order with poor locality at batch 1), except when
//! the layer's weight image fits the device cache, where refetch is
//! free after the first frame. Clustering shrinks the weight image to
//! ceil(log2 C) bits/param + a codebook, and uint8 shrinks both terms —
//! exactly the mechanisms behind the paper's 1.10-1.25x speedups.

use super::device::DeviceProfile;
use crate::compression::codec::index_bits;
use crate::models::flops::{inference_costs, LayerCost};
use crate::models::ModelSpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    /// post-training uint8 quantization (Table 2's right column)
    U8,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightFormat {
    Dense,
    /// weight-clustered with C active centroids
    Clustered { c: usize },
}

/// Fraction of peak achieved on streaming activation traffic.
const STREAM_EFFICIENCY: f64 = 0.6;

fn weight_image_bytes(cost: &LayerCost, prec: Precision, fmt: WeightFormat) -> f64 {
    let params = cost.weight_bytes as f64 / 4.0;
    match fmt {
        WeightFormat::Dense => match prec {
            Precision::F32 => params * 4.0,
            Precision::U8 => params,
        },
        WeightFormat::Clustered { c } => {
            // index stream + codebook (codebook entries at the precision)
            let elem = match prec {
                Precision::F32 => 4.0,
                Precision::U8 => 1.0,
            };
            params * index_bits(c) as f64 / 8.0 + c as f64 * elem
        }
    }
}

fn layer_latency_us(
    d: &DeviceProfile,
    cost: &LayerCost,
    prec: Precision,
    fmt: WeightFormat,
    weights_resident: bool,
) -> f64 {
    let compute_rate = match prec {
        Precision::F32 => d.f32_gflops,
        Precision::U8 => d.int8_gops,
    };
    let compute_us = cost.flops as f64 / compute_rate / 1e3;

    // cache residency is a *model-level* property: all layers' weight
    // images compete for the cache across one frame, so either the whole
    // model stays resident between frames (steady-state refetch ~ free)
    // or every layer streams its weights from DRAM each frame
    let weight_us = if weights_resident {
        0.0
    } else {
        weight_image_bytes(cost, prec, fmt) / (d.dram_gbps * d.weight_fetch_eff) / 1e3
    };

    let elem = match prec {
        Precision::F32 => 1.0,
        Precision::U8 => 0.25,
    };
    let act_us =
        cost.activation_bytes as f64 * elem / (d.dram_gbps * STREAM_EFFICIENCY) / 1e3;

    compute_us + weight_us + act_us + d.layer_overhead_us
}

/// Total weight image of the model in a given format/precision.
pub fn model_weight_bytes(spec: &ModelSpec, prec: Precision, fmt: WeightFormat) -> f64 {
    inference_costs(spec)
        .iter()
        .map(|c| weight_image_bytes(c, prec, fmt))
        .sum()
}

/// End-to-end batch-1 inference latency in microseconds.
pub fn inference_latency(
    spec: &ModelSpec,
    d: &DeviceProfile,
    prec: Precision,
    fmt: WeightFormat,
) -> f64 {
    let resident = model_weight_bytes(spec, prec, fmt) <= d.cache_kib * 1024.0;
    inference_costs(spec)
        .iter()
        .map(|c| layer_latency_us(d, c, prec, fmt, resident))
        .sum()
}

/// Speedup of a clustered model over the dense FedAvg model at the same
/// precision — exactly the Table 2 quantity.
pub fn speedup(spec: &ModelSpec, d: &DeviceProfile, prec: Precision, c: usize) -> f64 {
    let dense = inference_latency(spec, d, prec, WeightFormat::Dense);
    let clustered = inference_latency(spec, d, prec, WeightFormat::Clustered { c });
    dense / clustered
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::device::EDGE_DEVICES;
    use crate::edge::paper_models::{mobilenet, resnet20};
    use crate::models::spec::tests::demo_json;

    fn demo_spec() -> ModelSpec {
        ModelSpec::from_manifest("demo", &demo_json()).unwrap()
    }

    #[test]
    fn clustered_is_never_slower() {
        for spec in [demo_spec(), resnet20(), mobilenet()] {
            for d in &EDGE_DEVICES {
                for prec in [Precision::F32, Precision::U8] {
                    let s = speedup(&spec, d, prec, 16);
                    assert!(s >= 0.999, "{} {}: {s}", spec.name, d.name);
                }
            }
        }
    }

    #[test]
    fn paper_scale_speedups_land_in_band() {
        // the Table 2 claim: clustered models accelerate inference by
        // ~1.1-1.25x on edge devices
        for spec in [resnet20(), mobilenet()] {
            for d in &EDGE_DEVICES {
                for prec in [Precision::F32, Precision::U8] {
                    let s = speedup(&spec, d, prec, 16);
                    assert!(
                        (1.01..1.6).contains(&s),
                        "{} on {} ({prec:?}): {s}",
                        spec.name,
                        d.name
                    );
                }
            }
        }
    }

    #[test]
    fn more_clusters_stream_more_bits() {
        let spec = resnet20();
        let d = &EDGE_DEVICES[0];
        let s8 = speedup(&spec, d, Precision::F32, 8);
        let s32 = speedup(&spec, d, Precision::F32, 32);
        assert!(s8 >= s32, "{s8} vs {s32}");
    }

    #[test]
    fn u8_latency_leq_f32() {
        for spec in [resnet20(), mobilenet()] {
            for d in &EDGE_DEVICES {
                let f = inference_latency(&spec, d, Precision::F32, WeightFormat::Dense);
                let q = inference_latency(&spec, d, Precision::U8, WeightFormat::Dense);
                assert!(q <= f, "{}: {q} vs {f}", d.name);
            }
        }
    }

    #[test]
    fn latency_positive_and_overhead_bounded() {
        let spec = demo_spec();
        let d = &EDGE_DEVICES[1];
        let lat = inference_latency(&spec, d, Precision::F32, WeightFormat::Dense);
        // 2 layers x 35us overhead is a lower bound
        assert!(lat >= 70.0);
        assert!(lat.is_finite());
    }

    #[test]
    fn tiny_models_see_no_speedup() {
        // our 20k-param testbed models fit cache even dense: the edge
        // mechanism correctly predicts ~no speedup for them
        let spec = demo_spec();
        let s = speedup(&spec, &EDGE_DEVICES[0], Precision::F32, 16);
        assert!(s < 1.05, "{s}");
    }
}
