//! Post-training uint8 quantization model (Table 2's right column).
//!
//! Symmetric per-tensor affine quantization: q = round(w / s), s =
//! max|w| / 127. Provides the quantize/dequantize pair plus the error
//! analysis the accuracy-impact tests use. On clustered models the
//! codebook (not the weights) is quantized, so the two compressions
//! compose losslessly with respect to the cluster structure.

/// Per-tensor symmetric scale for int8. `max|w|` runs through
/// [`crate::kernels::abs_max`] — identical to a float fold for finite
/// weights; a NaN weight yields a NaN scale (the fold skipped NaNs),
/// which the downstream error analysis surfaces rather than hides.
pub fn scale_for(weights: &[f32]) -> f32 {
    let max = crate::kernels::abs_max(weights);
    if max == 0.0 {
        1.0
    } else {
        max / 127.0
    }
}

pub fn quantize(weights: &[f32], scale: f32) -> Vec<i8> {
    weights
        .iter()
        .map(|&w| (w / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// RMS quantization error relative to weight RMS.
pub fn relative_rms_error(weights: &[f32]) -> f64 {
    let s = scale_for(weights);
    let q = quantize(weights, s);
    let dq = dequantize(&q, s);
    let mut err = 0.0f64;
    let mut norm = 0.0f64;
    for (&w, &d) in weights.iter().zip(&dq) {
        err += ((w - d) as f64).powi(2);
        norm += (w as f64).powi(2);
    }
    if norm == 0.0 {
        0.0
    } else {
        (err / norm).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_is_small() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..5000).map(|_| rng.normal() * 0.1).collect();
        let e = relative_rms_error(&w);
        assert!(e < 0.01, "rms error {e}"); // 8-bit ~ 0.2-0.5% for gaussians
    }

    #[test]
    fn quantize_clamps() {
        let w = vec![10.0f32, -10.0, 0.0];
        let s = scale_for(&w);
        let q = quantize(&w, s);
        assert_eq!(q[0], 127);
        assert_eq!(q[1], -127);
        assert_eq!(q[2], 0);
    }

    #[test]
    fn zero_vector_is_stable() {
        let w = vec![0.0f32; 10];
        assert_eq!(scale_for(&w), 1.0);
        assert_eq!(relative_rms_error(&w), 0.0);
    }

    #[test]
    fn clustered_codebook_quantization_preserves_structure() {
        // quantizing a 16-entry codebook keeps entries distinct
        let cb: Vec<f32> = (0..16).map(|i| -0.8 + 0.1 * i as f32).collect();
        let s = scale_for(&cb);
        let q = quantize(&cb, s);
        let mut uniq = q.clone();
        uniq.dedup();
        assert_eq!(uniq.len(), 16);
    }
}
