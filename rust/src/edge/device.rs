//! Edge-device profiles (public spec-sheet numbers, batch-1 regime).

#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// sustained f32 GFLOP/s at batch 1 (well below peak: launch-bound)
    pub f32_gflops: f64,
    /// sustained int8 GOP/s
    pub int8_gops: f64,
    /// effective DRAM bandwidth, GB/s
    pub dram_gbps: f64,
    /// effective weight-resident on-chip capacity, KiB — the share of
    /// cache/scratchpad a steady-state NN workload can keep weights in
    /// (well below the nominal cache size: activations, im2col buffers
    /// and other processes contend for it)
    pub cache_kib: f64,
    /// fixed per-layer dispatch overhead, microseconds
    pub layer_overhead_us: f64,
    /// fraction of peak DRAM bandwidth achieved on weight fetches
    /// (GPUs coalesce far better than mobile CPU GEMM tiles)
    pub weight_fetch_eff: f64,
}

/// The three devices of Table 2.
pub const EDGE_DEVICES: [DeviceProfile; 3] = [
    // Pixel 6 (Tensor SoC, big-core CPU + TPU-lite offload; batch-1
    // CNN inference is mostly bandwidth/dispatch bound)
    DeviceProfile {
        name: "Pixel 6",
        f32_gflops: 40.0,
        int8_gops: 160.0,
        dram_gbps: 25.0,
        cache_kib: 192.0,
        layer_overhead_us: 18.0,
        weight_fetch_eff: 0.3,
    },
    // Jetson Nano (Maxwell 128-core GPU)
    DeviceProfile {
        name: "Jetson Nano",
        f32_gflops: 235.0,
        int8_gops: 470.0,
        dram_gbps: 20.0,
        cache_kib: 256.0,
        layer_overhead_us: 35.0,
        weight_fetch_eff: 0.75,
    },
    // Coral Edge TPU (int8-native systolic array; f32 falls back to the
    // host CPU path)
    DeviceProfile {
        name: "Coral TPU",
        f32_gflops: 30.0,
        int8_gops: 2000.0,
        dram_gbps: 12.0,
        cache_kib: 128.0,
        layer_overhead_us: 25.0,
        weight_fetch_eff: 0.5,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_are_sane() {
        for d in &EDGE_DEVICES {
            assert!(d.f32_gflops > 0.0);
            assert!(d.int8_gops >= d.f32_gflops);
            assert!(d.dram_gbps > 0.0);
            assert!(d.layer_overhead_us > 0.0);
        }
        assert_eq!(EDGE_DEVICES.len(), 3);
    }
}
