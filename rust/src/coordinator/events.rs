//! Structured event log for federated runs — the observability layer a
//! deployed coordinator needs: every dispatch, upload, aggregation, SCS
//! pass, controller decision, dropout and deadline cut as a typed
//! record, queryable by round and serializable to/from JSON lines.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Where in the round a client was lost (see `sim::ClientFate`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropPhase {
    /// Lost before local training started.
    BeforeTrain,
    /// Lost between training and upload — the client's local work never
    /// reached the server (and is elided by the simulation).
    BeforeUpload,
}

impl DropPhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            DropPhase::BeforeTrain => "train",
            DropPhase::BeforeUpload => "upload",
        }
    }
}

impl std::str::FromStr for DropPhase {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<DropPhase> {
        match s {
            "train" => Ok(DropPhase::BeforeTrain),
            "upload" => Ok(DropPhase::BeforeUpload),
            other => bail!("unknown drop phase '{other}'"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    RoundStart {
        round: usize,
        clusters: usize,
    },
    Dispatch {
        round: usize,
        client: usize,
        bytes: usize,
        compressed: bool,
    },
    Upload {
        round: usize,
        client: usize,
        bytes: usize,
        score: f64,
        mean_ce: f64,
    },
    Aggregated {
        round: usize,
        clients: usize,
        score: f64,
    },
    SelfCompress {
        round: usize,
        mean_kl: f64,
    },
    ControllerGrow {
        round: usize,
        from: usize,
        to: usize,
    },
    Evaluated {
        round: usize,
        accuracy: f64,
        loss: f64,
    },
    /// A selected client was lost to a fleet fault this round.
    Dropout {
        round: usize,
        client: usize,
        phase: DropPhase,
    },
    /// A client missed the reporting deadline; `sim_s` is the simulated
    /// completion time it would have needed.
    Deadline {
        round: usize,
        client: usize,
        sim_s: f64,
    },
    /// A checkpoint was resumed under a different transport kind or
    /// fleet preset than it was produced under. The run proceeds, but
    /// comparability with the original is no longer guaranteed.
    ResumeMismatch {
        /// round the resumed run starts at
        round: usize,
        ckpt_transport: String,
        ckpt_fleet: String,
        run_transport: String,
        run_fleet: String,
    },
}

impl Event {
    pub fn round(&self) -> usize {
        match self {
            Event::RoundStart { round, .. }
            | Event::Dispatch { round, .. }
            | Event::Upload { round, .. }
            | Event::Aggregated { round, .. }
            | Event::SelfCompress { round, .. }
            | Event::ControllerGrow { round, .. }
            | Event::Evaluated { round, .. }
            | Event::Dropout { round, .. }
            | Event::Deadline { round, .. }
            | Event::ResumeMismatch { round, .. } => *round,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Event::RoundStart { .. } => "round_start",
            Event::Dispatch { .. } => "dispatch",
            Event::Upload { .. } => "upload",
            Event::Aggregated { .. } => "aggregated",
            Event::SelfCompress { .. } => "self_compress",
            Event::ControllerGrow { .. } => "controller_grow",
            Event::Evaluated { .. } => "evaluated",
            Event::Dropout { .. } => "dropout",
            Event::Deadline { .. } => "deadline",
            Event::ResumeMismatch { .. } => "resume_mismatch",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("kind", Json::str(self.kind())),
            ("round", Json::from(self.round())),
        ];
        match self {
            Event::RoundStart { clusters, .. } => {
                pairs.push(("clusters", Json::from(*clusters)));
            }
            Event::Dispatch {
                client,
                bytes,
                compressed,
                ..
            } => {
                pairs.push(("client", Json::from(*client)));
                pairs.push(("bytes", Json::from(*bytes)));
                pairs.push(("compressed", Json::from(*compressed)));
            }
            Event::Upload {
                client,
                bytes,
                score,
                mean_ce,
                ..
            } => {
                pairs.push(("client", Json::from(*client)));
                pairs.push(("bytes", Json::from(*bytes)));
                pairs.push(("score", Json::num(*score)));
                pairs.push(("mean_ce", Json::num(*mean_ce)));
            }
            Event::Aggregated { clients, score, .. } => {
                pairs.push(("clients", Json::from(*clients)));
                pairs.push(("score", Json::num(*score)));
            }
            Event::SelfCompress { mean_kl, .. } => {
                pairs.push(("mean_kl", Json::num(*mean_kl)));
            }
            Event::ControllerGrow { from, to, .. } => {
                pairs.push(("from", Json::from(*from)));
                pairs.push(("to", Json::from(*to)));
            }
            Event::Evaluated { accuracy, loss, .. } => {
                pairs.push(("accuracy", Json::num(*accuracy)));
                pairs.push(("loss", Json::num(*loss)));
            }
            Event::Dropout { client, phase, .. } => {
                pairs.push(("client", Json::from(*client)));
                pairs.push(("phase", Json::str(phase.as_str())));
            }
            Event::Deadline { client, sim_s, .. } => {
                pairs.push(("client", Json::from(*client)));
                pairs.push(("sim_s", Json::num(*sim_s)));
            }
            Event::ResumeMismatch {
                ckpt_transport,
                ckpt_fleet,
                run_transport,
                run_fleet,
                ..
            } => {
                pairs.push(("ckpt_transport", Json::str(ckpt_transport)));
                pairs.push(("ckpt_fleet", Json::str(ckpt_fleet)));
                pairs.push(("run_transport", Json::str(run_transport)));
                pairs.push(("run_fleet", Json::str(run_fleet)));
            }
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Event::to_json`]: rebuild the typed event from its
    /// JSON record (the observability consumers' ingestion path).
    pub fn from_json(j: &Json) -> Result<Event> {
        let kind = j.get("kind")?.as_str()?;
        let round = j.get("round")?.as_usize()?;
        Ok(match kind {
            "round_start" => Event::RoundStart {
                round,
                clusters: j.get("clusters")?.as_usize()?,
            },
            "dispatch" => Event::Dispatch {
                round,
                client: j.get("client")?.as_usize()?,
                bytes: j.get("bytes")?.as_usize()?,
                compressed: j.get("compressed")?.as_bool()?,
            },
            "upload" => Event::Upload {
                round,
                client: j.get("client")?.as_usize()?,
                bytes: j.get("bytes")?.as_usize()?,
                score: j.get("score")?.as_f64()?,
                mean_ce: j.get("mean_ce")?.as_f64()?,
            },
            "aggregated" => Event::Aggregated {
                round,
                clients: j.get("clients")?.as_usize()?,
                score: j.get("score")?.as_f64()?,
            },
            "self_compress" => Event::SelfCompress {
                round,
                mean_kl: j.get("mean_kl")?.as_f64()?,
            },
            "controller_grow" => Event::ControllerGrow {
                round,
                from: j.get("from")?.as_usize()?,
                to: j.get("to")?.as_usize()?,
            },
            "evaluated" => Event::Evaluated {
                round,
                accuracy: j.get("accuracy")?.as_f64()?,
                loss: j.get("loss")?.as_f64()?,
            },
            "dropout" => Event::Dropout {
                round,
                client: j.get("client")?.as_usize()?,
                phase: j.get("phase")?.as_str()?.parse()?,
            },
            "deadline" => Event::Deadline {
                round,
                client: j.get("client")?.as_usize()?,
                sim_s: j.get("sim_s")?.as_f64()?,
            },
            "resume_mismatch" => Event::ResumeMismatch {
                round,
                ckpt_transport: j.get("ckpt_transport")?.as_str()?.to_string(),
                ckpt_fleet: j.get("ckpt_fleet")?.as_str()?.to_string(),
                run_transport: j.get("run_transport")?.as_str()?.to_string(),
                run_fleet: j.get("run_fleet")?.as_str()?.to_string(),
            },
            other => bail!("unknown event kind '{other}'"),
        })
    }
}

/// Append-only event log.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn all(&self) -> &[Event] {
        &self.events
    }

    pub fn for_round(&self, round: usize) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(move |e| e.round() == round)
    }

    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a Event> {
        self.events.iter().filter(move |e| e.kind() == kind)
    }

    /// JSON-lines serialization (one event per line). Every line carries
    /// a monotonic `seq` field — its position in the log — so consumers
    /// can detect gaps (a bounded sink that dropped events) and order
    /// merged streams without any wall-clock reads.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for (seq, e) in self.events.iter().enumerate() {
            let mut j = e.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("seq".to_string(), Json::from(seq));
            }
            s.push_str(&j.to_string());
            s.push('\n');
        }
        s
    }

    /// Parse a JSON-lines dump back into a typed log (inverse of
    /// [`EventLog::to_jsonl`]; blank lines are skipped).
    ///
    /// Tolerant by design: a malformed line is recorded as an
    /// [`EventParseError`] with its 1-based line number and parsing
    /// continues — a truncated or bit-flipped event file never aborts a
    /// replay, it just reports how much of it was unreadable. Logs
    /// written before the `seq` field existed decode unchanged (the
    /// field is ignored on input and regenerated from position).
    pub fn from_jsonl(text: &str) -> ParsedLog {
        let mut log = EventLog::new();
        let mut errors = Vec::new();
        for (idx, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line).and_then(|j| Event::from_json(&j));
            match parsed {
                Ok(e) => log.push(e),
                Err(e) => errors.push(EventParseError {
                    line: idx + 1,
                    error: e.to_string(),
                }),
            }
        }
        ParsedLog { log, errors }
    }
}

/// A single unreadable line in a JSON-lines event dump.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EventParseError {
    /// 1-based line number in the source text.
    pub line: usize,
    pub error: String,
}

/// Result of the tolerant [`EventLog::from_jsonl`]: everything that
/// parsed, plus a per-line error report for everything that did not.
#[derive(Clone, Debug, Default)]
pub struct ParsedLog {
    pub log: EventLog,
    pub errors: Vec<EventParseError>,
}

impl ParsedLog {
    /// True when every non-blank line parsed.
    pub fn is_clean(&self) -> bool {
        self.errors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn demo_log() -> EventLog {
        let mut log = EventLog::new();
        log.push(Event::RoundStart {
            round: 0,
            clusters: 16,
        });
        log.push(Event::Dispatch {
            round: 0,
            client: 2,
            bytes: 1000,
            compressed: false,
        });
        log.push(Event::Upload {
            round: 0,
            client: 2,
            bytes: 200,
            score: 4.5,
            mean_ce: 2.1,
        });
        log.push(Event::ControllerGrow {
            round: 1,
            from: 16,
            to: 24,
        });
        log
    }

    #[test]
    fn query_by_round_and_kind() {
        let log = demo_log();
        assert_eq!(log.for_round(0).count(), 3);
        assert_eq!(log.for_round(1).count(), 1);
        assert_eq!(log.of_kind("upload").count(), 1);
    }

    #[test]
    fn jsonl_is_parseable() {
        let log = demo_log();
        for line in log.to_jsonl().lines() {
            let j = Json::parse(line).unwrap();
            assert!(j.get("kind").is_ok());
            assert!(j.get("round").is_ok());
        }
    }

    #[test]
    fn grow_event_fields() {
        let log = demo_log();
        let e = log.of_kind("controller_grow").next().unwrap();
        let j = e.to_json();
        assert_eq!(j.get("from").unwrap().as_usize().unwrap(), 16);
        assert_eq!(j.get("to").unwrap().as_usize().unwrap(), 24);
    }

    /// One event of every variant, with awkward float payloads.
    fn full_log() -> EventLog {
        let mut log = demo_log();
        log.push(Event::Aggregated {
            round: 1,
            clients: 3,
            score: 4.062499999999999,
        });
        log.push(Event::SelfCompress {
            round: 1,
            mean_kl: 0.001953125,
        });
        log.push(Event::Evaluated {
            round: 1,
            accuracy: 0.7182818284590452,
            loss: 1.25e-3,
        });
        log.push(Event::Dropout {
            round: 2,
            client: 5,
            phase: DropPhase::BeforeTrain,
        });
        log.push(Event::Dropout {
            round: 2,
            client: 6,
            phase: DropPhase::BeforeUpload,
        });
        log.push(Event::Deadline {
            round: 2,
            client: 7,
            sim_s: 31.4159,
        });
        log.push(Event::ResumeMismatch {
            round: 3,
            ckpt_transport: "inproc".into(),
            ckpt_fleet: "ideal".into(),
            run_transport: "tcp".into(),
            run_fleet: "mobile".into(),
        });
        log
    }

    #[test]
    fn jsonl_round_trips_every_variant() {
        let log = full_log();
        let parsed = EventLog::from_jsonl(&log.to_jsonl());
        assert!(parsed.is_clean());
        assert_eq!(parsed.log.all(), log.all());
        // and once more through text, to prove the fixpoint (seq is the
        // line index, so regeneration reproduces it exactly)
        assert_eq!(parsed.log.to_jsonl(), log.to_jsonl());
    }

    #[test]
    fn every_line_carries_its_sequence_number() {
        let log = full_log();
        for (i, line) in log.to_jsonl().lines().enumerate() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), i);
        }
        // pre-seq logs (no seq field) still decode
        let legacy = "{\"kind\":\"round_start\",\"round\":0,\"clusters\":4}\n";
        let parsed = EventLog::from_jsonl(legacy);
        assert!(parsed.is_clean());
        assert_eq!(parsed.log.len(), 1);
    }

    #[test]
    fn dropout_and_deadline_serialize_their_fields() {
        let log = full_log();
        assert_eq!(log.of_kind("dropout").count(), 2);
        let j = log.of_kind("dropout").next().unwrap().to_json();
        assert_eq!(j.get("phase").unwrap().as_str().unwrap(), "train");
        assert_eq!(j.get("client").unwrap().as_usize().unwrap(), 5);
        let j = log.of_kind("deadline").next().unwrap().to_json();
        assert!((j.get("sim_s").unwrap().as_f64().unwrap() - 31.4159).abs() < 1e-12);
        let j = log.of_kind("resume_mismatch").next().unwrap().to_json();
        assert_eq!(j.get("ckpt_transport").unwrap().as_str().unwrap(), "inproc");
        assert_eq!(j.get("run_transport").unwrap().as_str().unwrap(), "tcp");
        assert_eq!(j.get("run_fleet").unwrap().as_str().unwrap(), "mobile");
        // phase strings parse back, garbage does not
        assert_eq!("upload".parse::<DropPhase>().unwrap(), DropPhase::BeforeUpload);
        assert!("sideways".parse::<DropPhase>().is_err());
    }

    #[test]
    fn malformed_lines_are_collected_not_fatal() {
        // missing fields, unknown kind, not JSON: each becomes a
        // per-line error, none aborts the parse
        let text = "{\"kind\":\"upload\",\"round\":0}\n\
                    {\"kind\":\"martian\",\"round\":0}\n\
                    not json at all\n";
        let parsed = EventLog::from_jsonl(text);
        assert_eq!(parsed.log.len(), 0);
        assert_eq!(parsed.errors.len(), 3);
        assert_eq!(
            parsed.errors.iter().map(|e| e.line).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );

        // good lines around a bad one survive, with the right line number
        let log = demo_log();
        let mut lines: Vec<&str> = Vec::new();
        let jsonl = log.to_jsonl();
        lines.extend(jsonl.lines());
        lines.insert(2, "garbage");
        let parsed = EventLog::from_jsonl(&lines.join("\n"));
        assert_eq!(parsed.log.len(), log.len());
        assert_eq!(parsed.errors.len(), 1);
        assert_eq!(parsed.errors[0].line, 3);

        // blank lines are fine and do not count as errors
        let padded = format!("\n{}\n\n", log.to_jsonl());
        let parsed = EventLog::from_jsonl(&padded);
        assert!(parsed.is_clean());
        assert_eq!(parsed.log.len(), log.len());
    }
}
