//! Structured event log for federated runs — the observability layer a
//! deployed coordinator needs: every dispatch, upload, aggregation, SCS
//! pass and controller decision as a typed record, queryable by round
//! and serializable to JSON lines.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    RoundStart {
        round: usize,
        clusters: usize,
    },
    Dispatch {
        round: usize,
        client: usize,
        bytes: usize,
        compressed: bool,
    },
    Upload {
        round: usize,
        client: usize,
        bytes: usize,
        score: f64,
        mean_ce: f64,
    },
    Aggregated {
        round: usize,
        clients: usize,
        score: f64,
    },
    SelfCompress {
        round: usize,
        mean_kl: f64,
    },
    ControllerGrow {
        round: usize,
        from: usize,
        to: usize,
    },
    Evaluated {
        round: usize,
        accuracy: f64,
        loss: f64,
    },
}

impl Event {
    pub fn round(&self) -> usize {
        match self {
            Event::RoundStart { round, .. }
            | Event::Dispatch { round, .. }
            | Event::Upload { round, .. }
            | Event::Aggregated { round, .. }
            | Event::SelfCompress { round, .. }
            | Event::ControllerGrow { round, .. }
            | Event::Evaluated { round, .. } => *round,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Event::RoundStart { .. } => "round_start",
            Event::Dispatch { .. } => "dispatch",
            Event::Upload { .. } => "upload",
            Event::Aggregated { .. } => "aggregated",
            Event::SelfCompress { .. } => "self_compress",
            Event::ControllerGrow { .. } => "controller_grow",
            Event::Evaluated { .. } => "evaluated",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("kind", Json::str(self.kind())),
            ("round", Json::from(self.round())),
        ];
        match self {
            Event::RoundStart { clusters, .. } => {
                pairs.push(("clusters", Json::from(*clusters)));
            }
            Event::Dispatch {
                client,
                bytes,
                compressed,
                ..
            } => {
                pairs.push(("client", Json::from(*client)));
                pairs.push(("bytes", Json::from(*bytes)));
                pairs.push(("compressed", Json::from(*compressed)));
            }
            Event::Upload {
                client,
                bytes,
                score,
                mean_ce,
                ..
            } => {
                pairs.push(("client", Json::from(*client)));
                pairs.push(("bytes", Json::from(*bytes)));
                pairs.push(("score", Json::num(*score)));
                pairs.push(("mean_ce", Json::num(*mean_ce)));
            }
            Event::Aggregated { clients, score, .. } => {
                pairs.push(("clients", Json::from(*clients)));
                pairs.push(("score", Json::num(*score)));
            }
            Event::SelfCompress { mean_kl, .. } => {
                pairs.push(("mean_kl", Json::num(*mean_kl)));
            }
            Event::ControllerGrow { from, to, .. } => {
                pairs.push(("from", Json::from(*from)));
                pairs.push(("to", Json::from(*to)));
            }
            Event::Evaluated { accuracy, loss, .. } => {
                pairs.push(("accuracy", Json::num(*accuracy)));
                pairs.push(("loss", Json::num(*loss)));
            }
        }
        Json::obj(pairs)
    }
}

/// Append-only event log.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn all(&self) -> &[Event] {
        &self.events
    }

    pub fn for_round(&self, round: usize) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(move |e| e.round() == round)
    }

    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a Event> {
        self.events.iter().filter(move |e| e.kind() == kind)
    }

    /// JSON-lines serialization (one event per line).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&e.to_json().to_string());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn demo_log() -> EventLog {
        let mut log = EventLog::new();
        log.push(Event::RoundStart { round: 0, clusters: 16 });
        log.push(Event::Dispatch {
            round: 0,
            client: 2,
            bytes: 1000,
            compressed: false,
        });
        log.push(Event::Upload {
            round: 0,
            client: 2,
            bytes: 200,
            score: 4.5,
            mean_ce: 2.1,
        });
        log.push(Event::ControllerGrow {
            round: 1,
            from: 16,
            to: 24,
        });
        log
    }

    #[test]
    fn query_by_round_and_kind() {
        let log = demo_log();
        assert_eq!(log.for_round(0).count(), 3);
        assert_eq!(log.for_round(1).count(), 1);
        assert_eq!(log.of_kind("upload").count(), 1);
    }

    #[test]
    fn jsonl_is_parseable() {
        let log = demo_log();
        for line in log.to_jsonl().lines() {
            let j = Json::parse(line).unwrap();
            assert!(j.get("kind").is_ok());
            assert!(j.get("round").is_ok());
        }
    }

    #[test]
    fn grow_event_fields() {
        let log = demo_log();
        let e = log.of_kind("controller_grow").next().unwrap();
        let j = e.to_json();
        assert_eq!(j.get("from").unwrap().as_usize().unwrap(), 16);
        assert_eq!(j.get("to").unwrap().as_usize().unwrap(), 24);
    }
}
