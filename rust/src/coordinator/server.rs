//! The federated server (paper Algorithm 1) as a strategy-agnostic
//! driver.
//!
//! Per round: `round_start` hook, dispatch the encoded model to the
//! selected clients (ledgered), run ClientUpdate on each, fan the
//! per-client upload encode out over `util::threadpool::parallel_map`,
//! `aggregate`, `post_aggregate` (where FedCompress's SelfCompress +
//! cluster growth live), then evaluate the *deliverable* model (the one
//! that would be dispatched next round) — which is what Table 1's
//! accuracy reports. Every per-strategy decision flows through the
//! [`FedStrategy`](super::strategy::FedStrategy) hooks; this file
//! contains no strategy branches.
//!
//! Parallelism: the PJRT engine wraps `Rc` and is thread-confined, so
//! the engine-bound *train* phase runs serially on the coordinator
//! thread (faithful to a single shared accelerator — XLA's intra-op
//! pool keeps the cores busy), while the pure-CPU *encode* phase
//! (k-means + Huffman, the dominant rust-side cost) runs on the worker
//! pool. Each client owns a deterministic RNG fork, so results are
//! independent of worker count and bit-identical to serial execution.

use anyhow::Result;

use super::events::{DropPhase, Event, EventLog};
use super::metrics::{RoundMetrics, RunResult};
use super::selection::select_clients;
use super::strategy::{
    ClientUpdate, FedStrategy, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::baselines::registry::StrategyRegistry;
use crate::baselines::wire::WireBlob;
use crate::client::trainer::{evaluate, train_local, ClientOutcome};
use crate::clustering::CentroidState;
use crate::compression::accounting::{CommLedger, Direction};
use crate::compression::codec::dense_bytes;
use crate::config::FedConfig;
use crate::data::{ood, partition::sigma_to_alpha, partition_dirichlet, synth, Dataset};
use crate::info;
use crate::models::flops::total_flops;
use crate::runtime::Engine;
use crate::sim::{ClientFate, FleetSim};
use crate::util::rng::Rng;
use crate::util::threadpool::{default_workers, parallel_map};

/// Everything a run needs in memory: client shards, unlabeled shards,
/// test split, server OOD set.
pub struct FederatedData {
    pub labeled: Vec<Dataset>,
    pub unlabeled: Vec<Dataset>,
    pub test: Dataset,
    pub ood: Dataset,
}

/// Materialize the synthetic federated environment for a config.
pub fn build_data(engine: &Engine, cfg: &FedConfig) -> Result<FederatedData> {
    let spec = synth::SynthSpec::for_dataset(&cfg.dataset);
    let domain = engine.manifest.dataset(&cfg.dataset)?.spec.domain.clone();
    let base = Rng::new(cfg.seed);

    let train = synth::generate(&spec, cfg.train_size, cfg.seed, 0);
    let test = synth::generate(&spec, cfg.test_size, cfg.seed, 1);
    let ood = ood::generate(&domain, spec.shape, cfg.ood_size, cfg.seed);

    let mut part_rng = base.fork(1);
    let alpha = sigma_to_alpha(cfg.sigma);
    let min_per = (cfg.unlabeled_per_client + 16).max(24);
    let shards = partition_dirichlet(&train, cfg.clients, alpha, min_per, &mut part_rng);

    let mut labeled = Vec::with_capacity(cfg.clients);
    let mut unlabeled = Vec::with_capacity(cfg.clients);
    for shard in shards {
        let (du, dl) = shard.take(cfg.unlabeled_per_client.min(shard.len() / 3));
        labeled.push(dl);
        unlabeled.push(du);
    }
    Ok(FederatedData {
        labeled,
        unlabeled,
        test,
        ood,
    })
}

/// One trained client awaiting upload encoding: the training outcome,
/// the client's RNG positioned exactly where training left it, and the
/// straggler slowdown the fault schedule assigned for this round.
struct TrainedClient {
    client: usize,
    outcome: ClientOutcome,
    rng: Rng,
    slowdown: f64,
}

/// Training FLOPs per sample per epoch: forward + backward is ~3x the
/// forward pass (the standard estimate the fleet clock runs on).
const TRAIN_FLOPS_FACTOR: f64 = 3.0;

/// Run one full federated training experiment for a registered
/// strategy name.
pub fn run_federated(engine: &Engine, cfg: &FedConfig, strategy: &str) -> Result<RunResult> {
    cfg.validate()?;
    let data = build_data(engine, cfg)?;
    run_federated_with_data(engine, cfg, strategy, &data)
}

/// Same, with externally supplied data (lets Table-1 drivers share one
/// environment across strategies so deltas are paired). Resolves
/// `strategy` against the built-in registry.
pub fn run_federated_with_data(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &str,
    data: &FederatedData,
) -> Result<RunResult> {
    let mut plugin = StrategyRegistry::builtin().build(strategy, cfg)?;
    run_with_strategy(engine, cfg, plugin.as_mut(), data)
}

/// The strategy-agnostic round loop. `strategy` must be a fresh
/// instance (stateful strategies assume one run per instance).
pub fn run_with_strategy(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &mut dyn FedStrategy,
    data: &FederatedData,
) -> Result<RunResult> {
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let spec = &engine.manifest.dataset(&cfg.dataset)?.spec;
    let p = spec.param_count;
    let c_max = engine.manifest.c_max;
    let sname = strategy.name();

    // fleet simulation: draws only from its own RNG streams, so the
    // default (ideal) fleet leaves every run byte-identical
    let sim = FleetSim::new(
        &cfg.fleet,
        cfg.clients,
        cfg.seed,
        TRAIN_FLOPS_FACTOR * total_flops(spec) as f64,
    );

    let theta = engine.init_theta(&cfg.dataset)?;
    anyhow::ensure!(theta.len() == p, "init theta size mismatch");

    // centroid table: strategies re-fit, learn, or ignore it per round
    let mut cents_rng = base.fork(2);
    let centroids =
        CentroidState::init_from_weights(&theta, cfg.controller.c_min, c_max, &mut cents_rng);
    let mut model = ServerModel { theta, centroids };

    let mut ledger = CommLedger::new();
    let mut events = EventLog::new();
    let mut rounds = Vec::with_capacity(cfg.rounds);
    let workers = match cfg.upload_workers {
        0 => default_workers().max(1),
        w => w,
    };

    for round in 0..cfg.rounds {
        let t0 = std::time::Instant::now();
        let mut round_rng = base.fork(100 + round as u64);
        let ctx = RoundContext {
            round,
            cfg,
            base: &base,
            // warmup: a few dense L_ce-only rounds before the
            // compression machinery engages (paper §1.2; DESIGN.md §3)
            compressing: round >= cfg.warmup_rounds,
            // the downstream is only clustered once SCS has run at least once
            down_compressed: round > cfg.warmup_rounds,
        };
        strategy.round_start(&ctx, &mut model)?;

        // --- dispatch ---------------------------------------------------
        events.push(Event::RoundStart {
            round,
            clusters: model.centroids.active,
        });
        let selected = select_clients(cfg.clients, cfg.participation, &mut round_rng)?;
        let fates = sim.round_fates(round, &selected);
        let down = strategy.encode_download(&ctx, &model)?;
        down.ensure_param_count(p)?;
        for &k in &selected {
            // the server pushes the dispatch before it can know which
            // clients will fault, so every selected client is ledgered
            ledger.record(round, Direction::Down, down.bytes);
            events.push(Event::Dispatch {
                round,
                client: k,
                bytes: down.bytes,
                compressed: down.bytes < 4 * p,
            });
        }

        // --- client updates (engine-bound, coordinator thread) ------------
        // Faulted clients never reach the server: their training (if
        // any) is discarded, so the engine work is skipped outright —
        // harmless, since every client owns an independent RNG fork.
        let opts = strategy.client_train_opts(&ctx);
        let mut trained = Vec::with_capacity(selected.len());
        let mut fault_drops = 0usize;
        for (&k, fate) in selected.iter().zip(&fates) {
            let phase = match fate {
                ClientFate::Healthy { .. } => None,
                ClientFate::DropBeforeTrain => Some(DropPhase::BeforeTrain),
                ClientFate::DropBeforeUpload => Some(DropPhase::BeforeUpload),
            };
            if let Some(phase) = phase {
                fault_drops += 1;
                events.push(Event::Dropout {
                    round,
                    client: k,
                    phase,
                });
                continue;
            }
            let mut client_rng = base.fork(10_000 + (round * cfg.clients + k) as u64);
            let outcome = train_local(
                engine,
                cfg,
                &data.labeled[k],
                &data.unlabeled[k],
                &down.theta,
                &model.centroids,
                opts.weight_clustering,
                &mut client_rng,
            )?;
            trained.push(TrainedClient {
                client: k,
                outcome,
                rng: client_rng,
                slowdown: fate.slowdown(),
            });
        }

        // --- upload encoding (pure CPU, worker pool) ----------------------
        let blobs: Vec<Result<WireBlob>> = {
            let strat: &dyn FedStrategy = &*strategy;
            let centroids = &model.centroids;
            let ctx = &ctx;
            parallel_map(trained.len(), workers, |i| {
                let t = &trained[i];
                // the client's learned centroids ride along for the snap
                let mut client_cents = centroids.clone();
                client_cents.mu.clone_from(&t.outcome.mu);
                let mut rng = t.rng.clone();
                strat.encode_upload(
                    ctx,
                    &UploadInput {
                        client: t.client,
                        theta: &t.outcome.theta,
                        centroids: &client_cents,
                    },
                    &mut rng,
                )
            })
        };

        // --- deadline + receive (simulated round clock) -------------------
        let mut uploads = Vec::with_capacity(trained.len());
        let mut ce_sum = 0.0f64;
        let mut up_bytes_round = 0usize;
        let mut max_reporting_s = 0.0f64;
        let mut deadline_drops = 0usize;
        for (t, blob) in trained.iter().zip(blobs) {
            let up = blob?;
            up.ensure_param_count(p)?;
            let sim_s = sim.client_time_s(
                t.client,
                down.bytes,
                up.bytes,
                data.labeled[t.client].len(),
                cfg.local_epochs,
                t.slowdown,
            );
            if sim.clock().over_deadline(sim_s) {
                deadline_drops += 1;
                events.push(Event::Deadline {
                    round,
                    client: t.client,
                    sim_s,
                });
                continue;
            }
            max_reporting_s = max_reporting_s.max(sim_s);
            ledger.record(round, Direction::Up, up.bytes);
            up_bytes_round += up.bytes;
            events.push(Event::Upload {
                round,
                client: t.client,
                bytes: up.bytes,
                score: t.outcome.score,
                mean_ce: t.outcome.mean_ce as f64,
            });
            ce_sum += t.outcome.mean_ce as f64;
            uploads.push(ClientUpdate {
                client: t.client,
                theta: up.theta,
                mu: t.outcome.mu.clone(),
                score: t.outcome.score,
                n: t.outcome.n,
            });
        }
        let dropped = fault_drops + deadline_drops;
        let stragglers = fates.iter().filter(|f| f.is_straggler()).count();
        let round_sim_ms = 1e3 * sim.clock().round_time_s(max_reporting_s, dropped > 0);

        // --- aggregate ----------------------------------------------------
        // survivors only; a fully lost round leaves the model untouched
        let score = if uploads.is_empty() {
            0.0
        } else {
            strategy.aggregate(&ctx, &mut model, &uploads)?
        };
        events.push(Event::Aggregated {
            round,
            clients: uploads.len(),
            score,
        });
        // active count reported for the round (before any growth below)
        let clusters = model.centroids.active;

        // --- strategy server-side work (SCS, controller, ...) -------------
        let env = ServerEnv {
            engine,
            cfg,
            data,
            base: &base,
        };
        if !uploads.is_empty() {
            strategy.post_aggregate(&ctx, &env, &mut model, score, &mut events)?;
        }

        // --- evaluate the deliverable model --------------------------------
        let (accuracy, test_loss) = evaluate(engine, &cfg.dataset, &data.test, &model.theta)?;
        events.push(Event::Evaluated {
            round,
            accuracy,
            loss: test_loss,
        });
        let m = RoundMetrics {
            round,
            accuracy,
            test_loss,
            score,
            // mean over the *survivors* the server actually heard from
            client_mean_ce: ce_sum / uploads.len().max(1) as f64,
            clusters,
            up_bytes: up_bytes_round,
            down_bytes: down.bytes * selected.len(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            round_sim_ms,
            stragglers,
            dropped,
        };
        info!(
            "[{}] {} round {:2}: acc={:.4} loss={:.3} E={:.2} C={} up={}B down={}B \
             sim={:.1}s drop={} strag={} ({:.0} ms)",
            sname,
            cfg.dataset,
            round,
            m.accuracy,
            m.test_loss,
            m.score,
            m.clusters,
            m.up_bytes,
            m.down_bytes,
            m.round_sim_ms / 1e3,
            m.dropped,
            m.stragglers,
            m.wall_ms
        );
        rounds.push(m);
    }

    // --- final deliverable + MCR ------------------------------------------
    let env = ServerEnv {
        engine,
        cfg,
        data,
        base: &base,
    };
    let final_model = strategy.finalize(&env, &model)?;
    let (final_accuracy, _) = evaluate(engine, &cfg.dataset, &data.test, &final_model.theta)?;

    Ok(RunResult {
        strategy: sname,
        dataset: cfg.dataset.clone(),
        rounds,
        final_theta: final_model.theta,
        final_accuracy,
        final_model_bytes: final_model.wire_bytes,
        dense_model_bytes: dense_bytes(p),
        ledger,
        events,
        final_centroids: model.centroids,
    })
}
