//! The federated server (paper Algorithm 1) as a strategy-agnostic,
//! transport-agnostic driver.
//!
//! Per round: `round_start` hook, dispatch the encoded model to the
//! selected clients (ledgered, with both ideal and framed byte
//! counts), hand the round and a streaming [`RoundIngest`] to the
//! configured [`Transport`] — which trains and encodes either in this
//! process (`net::InProcess`, the default) or on remote worker
//! processes over multiplexed framed TCP (`net::TcpTransport`). The
//! transport resolves each participant slot as its result arrives and
//! the ingest folds survivors straight into the strategy's
//! [`AggFold`] in canonical client-id order — constant memory in
//! fleet size, bit-identical to the historical buffered reduce — then
//! `aggregate` commits the fold, `post_aggregate` runs (where
//! FedCompress's SelfCompress + cluster growth live), and the
//! *deliverable* model (the one that would be dispatched next round)
//! is evaluated — which is what Table 1's accuracy reports. Every
//! per-strategy decision flows through the
//! [`FedStrategy`](super::strategy::FedStrategy) hooks; every
//! per-backend decision flows through the
//! [`Transport`](crate::net::Transport) trait; this file contains no
//! strategy and no transport branches.
//!
//! Losses from any source — sim-scheduled faults, sim deadline cuts,
//! and (TCP only) dead workers or real per-client timeouts — land in
//! the same `Event::Dropout`/`Event::Deadline` machinery, so a real
//! straggler is indistinguishable from a simulated one downstream.

use anyhow::Result;

use super::accumulate::{AggError, AggFold, AggOutput, StreamAccumulator};
use super::checkpoint::Checkpoint;
use super::events::{DropPhase, Event, EventLog};
use super::metrics::{RoundMetrics, RunResult};
use super::selection::select_clients;
use super::strategy::{ClientUpdate, FedStrategy, RoundContext, ServerEnv, ServerModel};
use crate::baselines::registry::StrategyRegistry;
use crate::client::trainer::evaluate;
use crate::clustering::CentroidState;
use crate::codec::StageBytes;
use crate::compression::accounting::{CommLedger, Direction};
use crate::compression::codec::dense_bytes;
use crate::config::FedConfig;
use crate::data::{ood, partition::sigma_to_alpha, partition_dirichlet, synth, Dataset};
use crate::info;
use crate::models::flops::total_flops;
use crate::net::proto::{framed_down, framed_up};
use crate::net::{ClientResult, InProcess, Participant, RoundEnv, RoundSpec, Transport};
use crate::obs::sink::{EventSink, NULL_SINK};
use crate::obs::stream::StreamEvent;
use crate::runtime::Engine;
use crate::sim::FleetSim;
use crate::util::rng::Rng;
use crate::util::threadpool::default_workers;
use crate::util::timer::Stopwatch;

/// Everything a run needs in memory: client shards, unlabeled shards,
/// test split, server OOD set.
pub struct FederatedData {
    pub labeled: Vec<Dataset>,
    pub unlabeled: Vec<Dataset>,
    pub test: Dataset,
    pub ood: Dataset,
}

/// Root RNG of a run. Part of the wire protocol's determinism
/// contract: TCP workers derive the same root from the config image.
pub fn run_rng(cfg: &FedConfig) -> Rng {
    // fedlint:allow(rng-discipline) -- this IS the named root constructor every stream forks from
    Rng::new(cfg.seed ^ 0xFEDC)
}

/// RNG stream id for client `k`'s local update in `round` — the other
/// half of the determinism contract (`net` module docs).
pub fn client_stream(round: usize, clients: usize, k: usize) -> u64 {
    10_000 + (round * clients + k) as u64
}

/// Materialize the synthetic federated environment for a config.
pub fn build_data(engine: &Engine, cfg: &FedConfig) -> Result<FederatedData> {
    let spec = synth::SynthSpec::for_dataset(&cfg.dataset);
    let domain = engine.manifest.dataset(&cfg.dataset)?.spec.domain.clone();
    // fedlint:allow(rng-discipline) -- seed-derived data stream root, part of the config-image contract
    let base = Rng::new(cfg.seed);

    let train = synth::generate(&spec, cfg.train_size, cfg.seed, 0);
    let test = synth::generate(&spec, cfg.test_size, cfg.seed, 1);
    let ood = ood::generate(&domain, spec.shape, cfg.ood_size, cfg.seed);

    let mut part_rng = base.fork(1);
    let alpha = sigma_to_alpha(cfg.sigma);
    let min_per = (cfg.unlabeled_per_client + 16).max(24);
    let shards = partition_dirichlet(&train, cfg.clients, alpha, min_per, &mut part_rng);

    let mut labeled = Vec::with_capacity(cfg.clients);
    let mut unlabeled = Vec::with_capacity(cfg.clients);
    for shard in shards {
        let (du, dl) = shard.take(cfg.unlabeled_per_client.min(shard.len() / 3));
        labeled.push(dl);
        unlabeled.push(du);
    }
    Ok(FederatedData {
        labeled,
        unlabeled,
        test,
        ood,
    })
}

/// Training FLOPs per sample per epoch: forward + backward is ~3x the
/// forward pass (the standard estimate the fleet clock runs on).
/// Public because edge-aggregator workers rebuild the same `FleetSim`
/// from the config image to apply the deadline clock locally.
pub const TRAIN_FLOPS_FACTOR: f64 = 3.0;

/// One member of an edge aggregator's pre-folded sub-round, as reported
/// upstream. The coordinator recomputes each member's simulated
/// reporting time from these values with the same pure clock the edge
/// used, so the two tiers always agree on deadline cuts.
#[derive(Clone, Copy, Debug)]
pub struct EdgeMember {
    pub client: usize,
    /// labeled sample count N_k (the member's FedAvg weight)
    pub n: usize,
    /// bytes the member uploaded to the edge tier (ledgered as Up)
    pub up_bytes: usize,
    pub score: f64,
    pub mean_ce: f32,
}

/// A sub-fleet member the edge aggregator cut at the simulated deadline.
#[derive(Clone, Copy, Debug)]
pub struct EdgeCutMember {
    pub client: usize,
    pub up_bytes: usize,
}

/// An edge aggregator's decoded upstream contribution: one pre-reduced
/// weighted mean over its surviving sub-fleet plus per-member metadata.
/// Folding `theta` with weight `total_n` reproduces the grand weighted
/// mean exactly in real arithmetic (group mean × group weight), so edge
/// runs stay deterministic — though not bit-identical to a flat run,
/// since the two-tier fold rounds differently.
#[derive(Clone, Debug)]
pub struct EdgePartial {
    pub theta: Vec<f32>,
    pub mu: Vec<f32>,
    /// weighted mean of member scores (weight = n)
    pub score: f64,
    /// Σ n over members — the group's fold weight
    pub total_n: usize,
    pub members: Vec<EdgeMember>,
    pub cut: Vec<EdgeCutMember>,
}

/// Per-slot terminal state recorded at resolve time and replayed in
/// canonical order by `finish`.
enum SlotMeta {
    Open,
    Dropped(DropPhase),
    TimedOut { elapsed_s: f64 },
    DeadlineCut { sim_s: f64 },
    Uploaded(Box<UploadMeta>),
}

/// The scalar sidecars of a survivor's upload — everything the event
/// stream, ledger, and round metrics need, with the heavy theta already
/// folded into the accumulator.
struct UploadMeta {
    bytes: usize,
    stage_bytes: Vec<StageBytes>,
    score: f64,
    mean_ce: f32,
    sim_s: f64,
}

/// What a finished ingest hands back to the round loop.
pub struct RoundIntake {
    /// `None` when no survivor carried weight (fully lost or zero-n
    /// round): the model stays untouched and the score reports 0.0.
    pub agg: Option<AggOutput>,
    pub survivors: usize,
    pub fault_drops: usize,
    pub deadline_drops: usize,
    pub ce_sum: f64,
    pub up_bytes: usize,
    pub max_reporting_s: f64,
    /// reorder-window high-water mark of the streaming accumulator
    pub peak_parked: usize,
    /// Transport-attributed wall ns per phase (`train`, `encode_up`,
    /// ...) — live-only observability input for the round loop's
    /// `phase_timing` ops event, never part of any record.
    pub phase_ns: Vec<(&'static str, u64)>,
}

/// Streaming ingest for one round. The transport resolves every
/// participant slot exactly once — upload, loss, or timeout — in any
/// arrival order; survivors' thetas are folded immediately at their
/// canonical (client-id-sorted) position via [`StreamAccumulator`], so
/// coordinator memory stays O(params + reorder window) instead of
/// O(fleet × params). Event and ledger emission is deferred to
/// [`RoundIngest::finish`], which replays the slots in canonical order
/// — the record stream is byte-identical to the historical buffered
/// loop no matter how the wire interleaved arrivals.
pub struct RoundIngest<'a> {
    round: usize,
    participants: &'a [Participant],
    sim: &'a FleetSim,
    samples: Vec<usize>,
    local_epochs: usize,
    down_bytes: usize,
    expected_params: usize,
    expected_mu: usize,
    accumulator: StreamAccumulator,
    outcomes: Vec<SlotMeta>,
    /// Live ops tee: per-slot resolutions (and, via the transport,
    /// evictions) stream here as they happen. Defaults to the
    /// [`NULL_SINK`]; never touches the canonical `EventLog`.
    sink: &'a dyn EventSink,
    /// Wall ns the transport attributes to named phases (see
    /// [`RoundIntake::phase_ns`]).
    phase_ns: Vec<(&'static str, u64)>,
}

impl<'a> RoundIngest<'a> {
    /// `participants` must be sorted by client id (the server sorts its
    /// selection) — slot index order IS the canonical fold order.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        round: usize,
        participants: &'a [Participant],
        sim: &'a FleetSim,
        data: &FederatedData,
        cfg: &FedConfig,
        down_bytes: usize,
        expected_params: usize,
        expected_mu: usize,
        fold: Box<dyn AggFold>,
    ) -> Self {
        debug_assert!(
            participants.windows(2).all(|w| w[0].client < w[1].client),
            "participants must be sorted by client id"
        );
        let samples = participants
            .iter()
            .map(|pt| data.labeled[pt.client].len())
            .collect();
        Self {
            round,
            participants,
            sim,
            samples,
            local_epochs: cfg.local_epochs,
            down_bytes,
            expected_params,
            expected_mu,
            accumulator: StreamAccumulator::new(fold, participants.len()),
            outcomes: (0..participants.len()).map(|_| SlotMeta::Open).collect(),
            sink: &NULL_SINK,
            phase_ns: Vec::new(),
        }
    }

    /// Attribute `ns` of wall time to `phase` (accumulating across
    /// calls). Transports use this for the phases only they can see —
    /// training vs upload-encoding — measured through `util::timer`.
    /// Timing is live-only by contract: it leaves `finish` on
    /// [`RoundIntake::phase_ns`] and goes nowhere but the ops stream.
    pub fn add_phase_ns(&mut self, phase: &'static str, ns: u64) {
        if let Some(entry) = self.phase_ns.iter_mut().find(|(p, _)| *p == phase) {
            entry.1 = entry.1.saturating_add(ns);
        } else {
            self.phase_ns.push((phase, ns));
        }
    }

    /// Route live per-slot ops events to `sink` for the rest of this
    /// round. The sink observes arrival order — deliberately *not* the
    /// canonical replay order `finish` produces.
    pub fn attach_sink(&mut self, sink: &'a dyn EventSink) {
        self.sink = sink;
    }

    /// The attached live sink (the transport emits eviction events
    /// through it).
    pub fn sink(&self) -> &dyn EventSink {
        self.sink
    }

    pub fn round(&self) -> usize {
        self.round
    }

    pub fn slots(&self) -> usize {
        self.participants.len()
    }

    /// Canonical slot of a client id, if it participates this round.
    pub fn slot_of(&self, client: usize) -> Option<usize> {
        self.participants
            .binary_search_by_key(&client, |pt| pt.client)
            .ok()
    }

    /// Number of parameters every decoded upload must carry.
    pub fn expected_params(&self) -> usize {
        self.expected_params
    }

    /// Length of the centroid table every upload's mu must match.
    pub fn expected_mu(&self) -> usize {
        self.expected_mu
    }

    /// Resolve one slot with its transport result. Uploads are deadline-
    /// checked on the simulated clock, then folded (or parked) at their
    /// canonical position; losses let the fold cursor move past them.
    pub fn resolve(&mut self, slot: usize, res: ClientResult) -> Result<()> {
        anyhow::ensure!(
            matches!(self.outcomes.get(slot), Some(SlotMeta::Open)),
            "participant slot {slot} resolved twice or out of range"
        );
        let part = self.participants[slot];
        match res {
            ClientResult::Dropped(phase) => {
                self.outcomes[slot] = SlotMeta::Dropped(phase);
                self.accumulator.resolve_lost(slot)?;
            }
            ClientResult::TimedOut { elapsed_s } => {
                self.outcomes[slot] = SlotMeta::TimedOut { elapsed_s };
                self.accumulator.resolve_lost(slot)?;
            }
            ClientResult::Upload(up) => {
                let u = *up;
                anyhow::ensure!(
                    u.client == part.client,
                    "upload for client {} resolved at client {}'s slot",
                    u.client,
                    part.client
                );
                u.blob.ensure_param_count(self.expected_params)?;
                let sim_s = self.sim.client_time_s(
                    part.client,
                    self.down_bytes,
                    u.blob.bytes,
                    self.samples[slot],
                    self.local_epochs,
                    part.fate.slowdown(),
                );
                if self.sim.clock().over_deadline(sim_s) {
                    self.outcomes[slot] = SlotMeta::DeadlineCut { sim_s };
                    self.accumulator.resolve_lost(slot)?;
                } else {
                    self.outcomes[slot] = SlotMeta::Uploaded(Box::new(UploadMeta {
                        bytes: u.blob.bytes,
                        stage_bytes: u.blob.stage_bytes,
                        score: u.score,
                        mean_ce: u.mean_ce,
                        sim_s,
                    }));
                    self.accumulator.resolve_upload(
                        slot,
                        ClientUpdate {
                            client: u.client,
                            theta: u.blob.theta,
                            mu: u.mu,
                            score: u.score,
                            n: u.n,
                        },
                    )?;
                }
            }
        }
        if self.sink.enabled() {
            // live arrival-order tee; `Open` is unreachable — the slot
            // was resolved just above
            let outcome = match self.outcomes.get(slot) {
                Some(SlotMeta::Dropped(phase)) => format!("drop_{}", phase.as_str()),
                Some(SlotMeta::TimedOut { .. }) => "timeout".to_string(),
                Some(SlotMeta::DeadlineCut { .. }) => "deadline".to_string(),
                Some(SlotMeta::Uploaded(_)) => "upload".to_string(),
                None | Some(SlotMeta::Open) => "open".to_string(),
            };
            self.sink.emit(&StreamEvent::Slot {
                round: self.round,
                client: part.client,
                outcome,
            });
        }
        Ok(())
    }

    /// Validate-then-commit an edge aggregator's pre-folded sub-round.
    /// `Err(reason)` means the message disagrees with the coordinator's
    /// own deterministic bookkeeping (unknown member, resolved slot,
    /// weight mismatch, deadline disagreement) — the transport should
    /// treat it as a protocol violation: evict the connection and drop
    /// its remaining slots. Nothing is mutated on rejection.
    pub fn resolve_edge(&mut self, partial: EdgePartial) -> std::result::Result<(), String> {
        // an all-cut sub-round legitimately carries an empty fold
        if !partial.members.is_empty() && partial.theta.len() != self.expected_params {
            return Err(format!(
                "edge theta carries {} params, expected {}",
                partial.theta.len(),
                self.expected_params
            ));
        }
        if !partial.members.is_empty() && partial.mu.len() != self.expected_mu {
            return Err(format!(
                "edge mu carries {} centroids, expected {}",
                partial.mu.len(),
                self.expected_mu
            ));
        }
        let n_sum: usize = partial.members.iter().map(|m| m.n).sum();
        if n_sum != partial.total_n {
            return Err(format!(
                "edge weight {} disagrees with member sum {n_sum}",
                partial.total_n
            ));
        }
        let open_slot = |client: usize| -> std::result::Result<usize, String> {
            let slot = self
                .slot_of(client)
                .ok_or_else(|| format!("edge member {client} is not a round participant"))?;
            match self.outcomes.get(slot) {
                Some(SlotMeta::Open) => Ok(slot),
                _ => Err(format!("edge member {client} already resolved")),
            }
        };
        // recompute every member's simulated reporting time with the
        // coordinator's own clock; the edge ran the same pure function,
        // so any disagreement on a cut is a lie, not a race
        let mut member_slots = Vec::with_capacity(partial.members.len());
        for m in &partial.members {
            let slot = open_slot(m.client)?;
            let sim_s = self.member_sim_s(slot, m.up_bytes);
            if self.sim.clock().over_deadline(sim_s) {
                return Err(format!("edge member {} is over the deadline but not cut", m.client));
            }
            member_slots.push((slot, sim_s));
        }
        let mut cut_slots = Vec::with_capacity(partial.cut.len());
        for c in &partial.cut {
            let slot = open_slot(c.client)?;
            let sim_s = self.member_sim_s(slot, c.up_bytes);
            if !self.sim.clock().over_deadline(sim_s) {
                return Err(format!("edge cut member {} beats the deadline", c.client));
            }
            cut_slots.push((slot, sim_s));
        }
        let mut seen: Vec<usize> = member_slots
            .iter()
            .chain(cut_slots.iter())
            .map(|&(slot, _)| slot)
            .collect();
        seen.sort_unstable();
        if seen.windows(2).any(|w| w[0] == w[1]) {
            return Err("duplicate client in edge upload".into());
        }

        // commit — all checks passed, so the accumulator calls below
        // cannot fail on slot state
        for &(slot, sim_s) in &cut_slots {
            self.outcomes[slot] = SlotMeta::DeadlineCut { sim_s };
            self.accumulator.resolve_lost(slot).map_err(|e| e.to_string())?;
        }
        let lead = member_slots.iter().map(|&(slot, _)| slot).min();
        for (&(slot, sim_s), m) in member_slots.iter().zip(&partial.members) {
            self.outcomes[slot] = SlotMeta::Uploaded(Box::new(UploadMeta {
                bytes: m.up_bytes,
                stage_bytes: Vec::new(),
                score: m.score,
                mean_ce: m.mean_ce,
                sim_s,
            }));
            if Some(slot) != lead {
                // folded through the lead slot's group update below
                self.accumulator.resolve_lost(slot).map_err(|e| e.to_string())?;
            }
        }
        if let Some(lead_slot) = lead {
            let group = ClientUpdate {
                client: self.participants[lead_slot].client,
                theta: partial.theta,
                mu: partial.mu,
                score: partial.score,
                n: partial.total_n,
            };
            self.accumulator
                .resolve_upload(lead_slot, group)
                .map_err(|e| e.to_string())?;
        }
        Ok(())
    }

    fn member_sim_s(&self, slot: usize, up_bytes: usize) -> f64 {
        let part = self.participants[slot];
        self.sim.client_time_s(
            part.client,
            self.down_bytes,
            up_bytes,
            self.samples[slot],
            self.local_epochs,
            part.fate.slowdown(),
        )
    }

    /// The deadline verdict [`RoundIngest::resolve_edge`] will recompute
    /// for `slot` uploading `up_bytes` — public so an in-process edge
    /// tier cuts exactly the members the coordinator's own clock would,
    /// instead of guessing and being rejected as a liar.
    pub fn member_over_deadline(&self, slot: usize, up_bytes: usize) -> bool {
        self.sim.clock().over_deadline(self.member_sim_s(slot, up_bytes))
    }

    /// Replay the resolved slots in canonical order — first every
    /// fault dropout, then deadlines/uploads with their ledger records
    /// — exactly the event and ledger sequence the buffered loop
    /// produced, then finish the fold.
    pub fn finish(self, ledger: &mut CommLedger, events: &mut EventLog) -> Result<RoundIntake> {
        let round = self.round;
        let mut intake = RoundIntake {
            agg: None,
            survivors: 0,
            fault_drops: 0,
            deadline_drops: 0,
            ce_sum: 0.0,
            up_bytes: 0,
            max_reporting_s: 0.0,
            peak_parked: self.accumulator.peak_parked(),
            phase_ns: self.phase_ns.clone(),
        };
        for (pt, m) in self.participants.iter().zip(&self.outcomes) {
            if let SlotMeta::Dropped(phase) = m {
                intake.fault_drops += 1;
                events.push(Event::Dropout {
                    round,
                    client: pt.client,
                    phase: *phase,
                });
            }
        }
        for (pt, m) in self.participants.iter().zip(&self.outcomes) {
            match m {
                SlotMeta::Open => {
                    anyhow::bail!("transport left client {} unresolved", pt.client)
                }
                SlotMeta::Dropped(_) => {}
                SlotMeta::TimedOut { elapsed_s } => {
                    // a *real* straggler cut by the transport's timeout
                    intake.deadline_drops += 1;
                    events.push(Event::Deadline {
                        round,
                        client: pt.client,
                        sim_s: *elapsed_s,
                    });
                }
                SlotMeta::DeadlineCut { sim_s } => {
                    intake.deadline_drops += 1;
                    events.push(Event::Deadline {
                        round,
                        client: pt.client,
                        sim_s: *sim_s,
                    });
                }
                SlotMeta::Uploaded(up) => {
                    intake.max_reporting_s = intake.max_reporting_s.max(up.sim_s);
                    ledger.record(round, Direction::Up, up.bytes, framed_up(up.bytes));
                    ledger.record_stages(Direction::Up, &up.stage_bytes);
                    intake.up_bytes += up.bytes;
                    events.push(Event::Upload {
                        round,
                        client: pt.client,
                        bytes: up.bytes,
                        score: up.score,
                        mean_ce: up.mean_ce as f64,
                    });
                    intake.ce_sum += up.mean_ce as f64;
                    intake.survivors += 1;
                }
            }
        }
        intake.agg = match self.accumulator.finish() {
            Ok(agg) => Some(agg),
            // fully lost or zero-weight round: model stays untouched
            Err(AggError::Empty) | Err(AggError::ZeroWeight) => None,
            Err(e) => return Err(e.into()),
        };
        Ok(intake)
    }
}

/// Run one full federated training experiment for a registered
/// strategy name.
pub fn run_federated(engine: &Engine, cfg: &FedConfig, strategy: &str) -> Result<RunResult> {
    cfg.validate()?;
    let data = build_data(engine, cfg)?;
    run_federated_with_data(engine, cfg, strategy, &data)
}

/// Same, with externally supplied data (lets Table-1 drivers share one
/// environment across strategies so deltas are paired). Resolves
/// `strategy` against the built-in registry.
pub fn run_federated_with_data(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &str,
    data: &FederatedData,
) -> Result<RunResult> {
    let mut plugin = StrategyRegistry::builtin().build(strategy, cfg)?;
    run_with_strategy(engine, cfg, plugin.as_mut(), data)
}

/// The strategy-agnostic round loop on the default in-process
/// transport. `strategy` must be a fresh instance (stateful strategies
/// assume one run per instance).
pub fn run_with_strategy(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &mut dyn FedStrategy,
    data: &FederatedData,
) -> Result<RunResult> {
    let mut transport = InProcess;
    run_with_strategy_opts(engine, cfg, strategy, data, &mut transport, None)
}

/// The full-control entry point: any [`Transport`] backend, optional
/// resume from a [`Checkpoint`]. A resumed run continues from the
/// checkpoint's round cursor with its theta/centroids; a checkpoint
/// produced under a different transport kind or fleet preset still
/// runs, but emits [`Event::ResumeMismatch`] so the divergence is on
/// the record.
pub fn run_with_strategy_opts(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &mut dyn FedStrategy,
    data: &FederatedData,
    transport: &mut dyn Transport,
    resume: Option<&Checkpoint>,
) -> Result<RunResult> {
    run_with_strategy_sink(engine, cfg, strategy, data, transport, resume, &NULL_SINK)
}

/// Tee every canonical event past the `teed` cursor to the live sink.
/// The cursor advances even when the sink is disabled, so attaching a
/// real sink costs nothing on the default path.
fn tee_events(sink: &dyn EventSink, events: &EventLog, teed: &mut usize) {
    if sink.enabled() {
        for e in events.all().iter().skip(*teed) {
            sink.emit(&StreamEvent::Run(e.clone()));
        }
    }
    *teed = events.len();
}

/// [`run_with_strategy_opts`] plus a live [`EventSink`]: every
/// canonical event is teed to `sink` as it lands in the run's
/// [`EventLog`], interleaved with ops-only detail (per-slot arrival
/// order, reorder-window depth, transport evictions, per-round
/// `RoundOps`) that never enters the bit-exact record. The sink
/// contract is non-blocking, so observability cannot perturb round
/// latency — and because the canonical log is written first and teed
/// after, it cannot perturb determinism either.
pub fn run_with_strategy_sink(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &mut dyn FedStrategy,
    data: &FederatedData,
    transport: &mut dyn Transport,
    resume: Option<&Checkpoint>,
    sink: &dyn EventSink,
) -> Result<RunResult> {
    let base = run_rng(cfg);
    let spec = &engine.manifest.dataset(&cfg.dataset)?.spec;
    let p = spec.param_count;
    let c_max = engine.manifest.c_max;
    let sname = strategy.name();

    // fleet simulation: draws only from its own RNG streams, so the
    // default (ideal) fleet leaves every run byte-identical
    let sim = FleetSim::new(
        &cfg.fleet,
        cfg.clients,
        cfg.seed,
        TRAIN_FLOPS_FACTOR * total_flops(spec) as f64,
    );

    let theta = engine.init_theta(&cfg.dataset)?;
    anyhow::ensure!(theta.len() == p, "init theta size mismatch");

    // centroid table: strategies re-fit, learn, or ignore it per round
    let mut cents_rng = base.fork(2);
    let centroids =
        CentroidState::init_from_weights(&theta, cfg.controller.c_min, c_max, &mut cents_rng);
    let mut model = ServerModel { theta, centroids };

    let mut ledger = CommLedger::new();
    let mut events = EventLog::new();
    // cursor into `events` marking what the live sink has already seen
    let mut teed = 0usize;
    let mut start_round = 0usize;
    if let Some(ckpt) = resume {
        anyhow::ensure!(
            ckpt.theta.len() == p,
            "checkpoint carries {} params, the {} model has {p}",
            ckpt.theta.len(),
            cfg.dataset
        );
        anyhow::ensure!(
            ckpt.round < cfg.rounds,
            "checkpoint is already at round {} of {}; raise `--set rounds=N` to continue",
            ckpt.round,
            cfg.rounds
        );
        model.theta = ckpt.theta.clone();
        model.centroids = ckpt.centroid_state();
        start_round = ckpt.round;
        // stateful strategies (FedCompress's plateau controller) replay
        // the recorded score history so continuation is exact
        strategy.resume(cfg, &ckpt.scores)?;
        let run_transport = transport.kind().name();
        let run_fleet = cfg.fleet.preset.name();
        if ckpt.transport != run_transport || ckpt.fleet != run_fleet {
            info!(
                "resume mismatch: checkpoint from transport={}/fleet={}, run is {}/{}",
                ckpt.transport, ckpt.fleet, run_transport, run_fleet
            );
            events.push(Event::ResumeMismatch {
                round: start_round,
                ckpt_transport: ckpt.transport.clone(),
                ckpt_fleet: ckpt.fleet.clone(),
                run_transport: run_transport.to_string(),
                run_fleet: run_fleet.to_string(),
            });
        }
    }
    tee_events(sink, &events, &mut teed);

    let mut rounds = Vec::with_capacity(cfg.rounds - start_round);
    let workers = match cfg.upload_workers {
        0 => default_workers().max(1),
        w => w,
    };

    for round in start_round..cfg.rounds {
        // wall clock only through the sanctioned timer: `wall_ms` is a
        // bench field excluded from record diffing, and the phase laps
        // below feed the live-only `phase_timing` ops event — neither
        // ever reaches canonical events or records
        let round_sw = Stopwatch::start();
        let mut phase_sw = Stopwatch::start();
        let mut round_rng = base.fork(100 + round as u64);
        let ctx = RoundContext {
            round,
            cfg,
            base: &base,
            // warmup: a few dense L_ce-only rounds before the
            // compression machinery engages (paper §1.2; DESIGN.md §3)
            compressing: round >= cfg.warmup_rounds,
            // the downstream is only clustered once SCS has run at least once
            down_compressed: round > cfg.warmup_rounds,
        };
        strategy.round_start(&ctx, &mut model)?;

        // --- dispatch ---------------------------------------------------
        events.push(Event::RoundStart {
            round,
            clusters: model.centroids.active,
        });
        let mut selected = select_clients(cfg.clients, cfg.participation, &mut round_rng)?;
        // canonical order: dispatch, events, and the streaming fold all
        // walk participants sorted by client id (fold determinism
        // contract — `coordinator::accumulate` module docs)
        selected.sort_unstable();
        let fates = sim.round_fates(round, &selected);
        let select_ns = phase_sw.lap_ns();
        let down = strategy.encode_download(&ctx, &model)?;
        down.ensure_param_count(p)?;
        let down_framed = framed_down(down.bytes);
        for &k in &selected {
            // the server pushes the dispatch before it can know which
            // clients will fault, so every selected client is ledgered
            ledger.record(round, Direction::Down, down.bytes, down_framed);
            ledger.record_stages(Direction::Down, &down.stage_bytes);
            events.push(Event::Dispatch {
                round,
                client: k,
                bytes: down.bytes,
                compressed: down.bytes < 4 * p,
            });
        }
        tee_events(sink, &events, &mut teed);
        let encode_down_ns = phase_sw.lap_ns();

        // --- client updates via the transport -----------------------------
        let participants: Vec<Participant> = selected
            .iter()
            .zip(&fates)
            .map(|(&client, &fate)| Participant { client, fate })
            .collect();
        let opts = strategy.client_train_opts(&ctx);
        let round_spec = RoundSpec {
            round,
            down: &down,
            centroids: &model.centroids,
            opts,
            compressing: ctx.compressing,
            down_compressed: ctx.down_compressed,
            participants: &participants,
        };
        let env = RoundEnv {
            engine,
            cfg,
            data,
            base: &base,
            encode_workers: workers,
        };
        let mut ingest = RoundIngest::new(
            round,
            &participants,
            &sim,
            data,
            cfg,
            down.bytes,
            p,
            model.centroids.mu.len(),
            strategy.make_fold(&ctx),
        );
        ingest.attach_sink(sink);
        transport.run_round(&env, &*strategy, &round_spec, &mut ingest)?;
        let transport_ns = phase_sw.lap_ns();
        // canonical-order replay: events + ledger byte-identical to the
        // buffered loop, survivors already folded
        let intake = ingest.finish(&mut ledger, &mut events)?;
        tee_events(sink, &events, &mut teed);
        let finish_ns = phase_sw.lap_ns();
        let dropped = intake.fault_drops + intake.deadline_drops;
        let stragglers = fates.iter().filter(|f| f.is_straggler()).count();
        let round_sim_ms = 1e3 * sim.clock().round_time_s(intake.max_reporting_s, dropped > 0);

        // --- aggregate ----------------------------------------------------
        // survivors only; a fully lost (or zero-weight) round leaves the
        // model untouched
        let survivors = intake.survivors;
        let aggregated = intake.agg.is_some();
        let score = match intake.agg {
            None => 0.0,
            Some(agg) => strategy.aggregate(&ctx, &mut model, agg)?,
        };
        events.push(Event::Aggregated {
            round,
            clients: survivors,
            score,
        });
        // active count reported for the round (before any growth below)
        let clusters = model.centroids.active;

        // --- strategy server-side work (SCS, controller, ...) -------------
        let env = ServerEnv {
            engine,
            cfg,
            data,
            base: &base,
        };
        if aggregated {
            strategy.post_aggregate(&ctx, &env, &mut model, score, &mut events)?;
        }
        tee_events(sink, &events, &mut teed);
        let aggregate_ns = phase_sw.lap_ns();

        // --- evaluate the deliverable model --------------------------------
        let (accuracy, test_loss) = evaluate(engine, &cfg.dataset, &data.test, &model.theta)?;
        events.push(Event::Evaluated {
            round,
            accuracy,
            loss: test_loss,
        });
        tee_events(sink, &events, &mut teed);
        let evaluate_ns = phase_sw.lap_ns();
        // ops-only round summary, emitted right after the round's last
        // canonical event — offline replay synthesizes RoundOps at the
        // same position, so live tee and record replay line up
        sink.emit(&StreamEvent::RoundOps {
            round,
            stragglers,
            peak_parked: intake.peak_parked,
            sim_ms: round_sim_ms,
        });
        // live-only phase profile: the transport attributes what only
        // it can see (train vs upload-encode); everything else in its
        // lap — wire wait, decode, slot resolution — plus the
        // canonical-order replay in `finish` is the ingest phase
        if sink.enabled() {
            let attributed = |name: &str| {
                intake
                    .phase_ns
                    .iter()
                    .find(|(p, _)| *p == name)
                    .map(|&(_, v)| v)
                    .unwrap_or(0)
            };
            let train_ns = attributed("train");
            let encode_up_ns = attributed("encode_up");
            let ingest_ns = transport_ns
                .saturating_sub(train_ns.saturating_add(encode_up_ns))
                .saturating_add(finish_ns);
            let mut ns: Vec<(String, u64)> = [
                ("select", select_ns),
                ("encode_down", encode_down_ns),
                ("train", train_ns),
                ("encode_up", encode_up_ns),
                ("ingest", ingest_ns),
                ("aggregate", aggregate_ns),
                ("evaluate", evaluate_ns),
            ]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect();
            // stream invariant: phase names sort ascending on the wire
            ns.sort_by(|a, b| a.0.cmp(&b.0));
            sink.emit(&StreamEvent::PhaseTiming { round, ns });
        }
        let m = RoundMetrics {
            round,
            accuracy,
            test_loss,
            score,
            // mean over the *survivors* the server actually heard from
            client_mean_ce: intake.ce_sum / survivors.max(1) as f64,
            clusters,
            up_bytes: intake.up_bytes,
            down_bytes: down.bytes * selected.len(),
            wall_ms: round_sw.elapsed_ms(),
            round_sim_ms,
            stragglers,
            dropped,
        };
        info!(
            "[{}] {} round {:2}: acc={:.4} loss={:.3} E={:.2} C={} up={}B down={}B \
             sim={:.1}s drop={} strag={} ({:.0} ms)",
            sname,
            cfg.dataset,
            round,
            m.accuracy,
            m.test_loss,
            m.score,
            m.clusters,
            m.up_bytes,
            m.down_bytes,
            m.round_sim_ms / 1e3,
            m.dropped,
            m.stragglers,
            m.wall_ms
        );
        rounds.push(m);
    }

    // --- final deliverable + MCR ------------------------------------------
    let env = ServerEnv {
        engine,
        cfg,
        data,
        base: &base,
    };
    let final_model = strategy.finalize(&env, &model)?;
    let (final_accuracy, _) = evaluate(engine, &cfg.dataset, &data.test, &final_model.theta)?;

    Ok(RunResult {
        strategy: sname,
        dataset: cfg.dataset.clone(),
        rounds,
        final_theta: final_model.theta,
        final_accuracy,
        final_model_bytes: final_model.wire_bytes,
        dense_model_bytes: dense_bytes(p),
        ledger,
        events,
        final_centroids: model.centroids,
    })
}
