//! The federated server (paper Algorithm 1) as a strategy-agnostic,
//! transport-agnostic driver.
//!
//! Per round: `round_start` hook, dispatch the encoded model to the
//! selected clients (ledgered, with both ideal and framed byte
//! counts), hand the round to the configured [`Transport`] — which
//! trains and encodes either in this process (`net::InProcess`, the
//! default) or on remote worker processes over framed TCP
//! (`net::TcpTransport`) — then fold the collected uploads through
//! `aggregate`, `post_aggregate` (where FedCompress's SelfCompress +
//! cluster growth live), and evaluate the *deliverable* model (the one
//! that would be dispatched next round) — which is what Table 1's
//! accuracy reports. Every per-strategy decision flows through the
//! [`FedStrategy`](super::strategy::FedStrategy) hooks; every
//! per-backend decision flows through the
//! [`Transport`](crate::net::Transport) trait; this file contains no
//! strategy and no transport branches.
//!
//! Losses from any source — sim-scheduled faults, sim deadline cuts,
//! and (TCP only) dead workers or real per-client timeouts — land in
//! the same `Event::Dropout`/`Event::Deadline` machinery, so a real
//! straggler is indistinguishable from a simulated one downstream.

use anyhow::Result;

use super::checkpoint::Checkpoint;
use super::events::{Event, EventLog};
use super::metrics::{RoundMetrics, RunResult};
use super::selection::select_clients;
use super::strategy::{ClientUpdate, FedStrategy, RoundContext, ServerEnv, ServerModel};
use crate::baselines::registry::StrategyRegistry;
use crate::client::trainer::evaluate;
use crate::clustering::CentroidState;
use crate::compression::accounting::{CommLedger, Direction};
use crate::compression::codec::dense_bytes;
use crate::config::FedConfig;
use crate::data::{ood, partition::sigma_to_alpha, partition_dirichlet, synth, Dataset};
use crate::info;
use crate::models::flops::total_flops;
use crate::net::proto::{framed_down, framed_up};
use crate::net::{ClientResult, InProcess, Participant, RoundEnv, RoundSpec, Transport};
use crate::runtime::Engine;
use crate::sim::FleetSim;
use crate::util::rng::Rng;
use crate::util::threadpool::default_workers;

/// Everything a run needs in memory: client shards, unlabeled shards,
/// test split, server OOD set.
pub struct FederatedData {
    pub labeled: Vec<Dataset>,
    pub unlabeled: Vec<Dataset>,
    pub test: Dataset,
    pub ood: Dataset,
}

/// Root RNG of a run. Part of the wire protocol's determinism
/// contract: TCP workers derive the same root from the config image.
pub fn run_rng(cfg: &FedConfig) -> Rng {
    // fedlint:allow(rng-discipline) -- this IS the named root constructor every stream forks from
    Rng::new(cfg.seed ^ 0xFEDC)
}

/// RNG stream id for client `k`'s local update in `round` — the other
/// half of the determinism contract (`net` module docs).
pub fn client_stream(round: usize, clients: usize, k: usize) -> u64 {
    10_000 + (round * clients + k) as u64
}

/// Materialize the synthetic federated environment for a config.
pub fn build_data(engine: &Engine, cfg: &FedConfig) -> Result<FederatedData> {
    let spec = synth::SynthSpec::for_dataset(&cfg.dataset);
    let domain = engine.manifest.dataset(&cfg.dataset)?.spec.domain.clone();
    // fedlint:allow(rng-discipline) -- seed-derived data stream root, part of the config-image contract
    let base = Rng::new(cfg.seed);

    let train = synth::generate(&spec, cfg.train_size, cfg.seed, 0);
    let test = synth::generate(&spec, cfg.test_size, cfg.seed, 1);
    let ood = ood::generate(&domain, spec.shape, cfg.ood_size, cfg.seed);

    let mut part_rng = base.fork(1);
    let alpha = sigma_to_alpha(cfg.sigma);
    let min_per = (cfg.unlabeled_per_client + 16).max(24);
    let shards = partition_dirichlet(&train, cfg.clients, alpha, min_per, &mut part_rng);

    let mut labeled = Vec::with_capacity(cfg.clients);
    let mut unlabeled = Vec::with_capacity(cfg.clients);
    for shard in shards {
        let (du, dl) = shard.take(cfg.unlabeled_per_client.min(shard.len() / 3));
        labeled.push(dl);
        unlabeled.push(du);
    }
    Ok(FederatedData {
        labeled,
        unlabeled,
        test,
        ood,
    })
}

/// Training FLOPs per sample per epoch: forward + backward is ~3x the
/// forward pass (the standard estimate the fleet clock runs on).
const TRAIN_FLOPS_FACTOR: f64 = 3.0;

/// Run one full federated training experiment for a registered
/// strategy name.
pub fn run_federated(engine: &Engine, cfg: &FedConfig, strategy: &str) -> Result<RunResult> {
    cfg.validate()?;
    let data = build_data(engine, cfg)?;
    run_federated_with_data(engine, cfg, strategy, &data)
}

/// Same, with externally supplied data (lets Table-1 drivers share one
/// environment across strategies so deltas are paired). Resolves
/// `strategy` against the built-in registry.
pub fn run_federated_with_data(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &str,
    data: &FederatedData,
) -> Result<RunResult> {
    let mut plugin = StrategyRegistry::builtin().build(strategy, cfg)?;
    run_with_strategy(engine, cfg, plugin.as_mut(), data)
}

/// The strategy-agnostic round loop on the default in-process
/// transport. `strategy` must be a fresh instance (stateful strategies
/// assume one run per instance).
pub fn run_with_strategy(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &mut dyn FedStrategy,
    data: &FederatedData,
) -> Result<RunResult> {
    let mut transport = InProcess;
    run_with_strategy_opts(engine, cfg, strategy, data, &mut transport, None)
}

/// The full-control entry point: any [`Transport`] backend, optional
/// resume from a [`Checkpoint`]. A resumed run continues from the
/// checkpoint's round cursor with its theta/centroids; a checkpoint
/// produced under a different transport kind or fleet preset still
/// runs, but emits [`Event::ResumeMismatch`] so the divergence is on
/// the record.
pub fn run_with_strategy_opts(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &mut dyn FedStrategy,
    data: &FederatedData,
    transport: &mut dyn Transport,
    resume: Option<&Checkpoint>,
) -> Result<RunResult> {
    let base = run_rng(cfg);
    let spec = &engine.manifest.dataset(&cfg.dataset)?.spec;
    let p = spec.param_count;
    let c_max = engine.manifest.c_max;
    let sname = strategy.name();

    // fleet simulation: draws only from its own RNG streams, so the
    // default (ideal) fleet leaves every run byte-identical
    let sim = FleetSim::new(
        &cfg.fleet,
        cfg.clients,
        cfg.seed,
        TRAIN_FLOPS_FACTOR * total_flops(spec) as f64,
    );

    let theta = engine.init_theta(&cfg.dataset)?;
    anyhow::ensure!(theta.len() == p, "init theta size mismatch");

    // centroid table: strategies re-fit, learn, or ignore it per round
    let mut cents_rng = base.fork(2);
    let centroids =
        CentroidState::init_from_weights(&theta, cfg.controller.c_min, c_max, &mut cents_rng);
    let mut model = ServerModel { theta, centroids };

    let mut ledger = CommLedger::new();
    let mut events = EventLog::new();
    let mut start_round = 0usize;
    if let Some(ckpt) = resume {
        anyhow::ensure!(
            ckpt.theta.len() == p,
            "checkpoint carries {} params, the {} model has {p}",
            ckpt.theta.len(),
            cfg.dataset
        );
        anyhow::ensure!(
            ckpt.round < cfg.rounds,
            "checkpoint is already at round {} of {}; raise `--set rounds=N` to continue",
            ckpt.round,
            cfg.rounds
        );
        model.theta = ckpt.theta.clone();
        model.centroids = ckpt.centroid_state();
        start_round = ckpt.round;
        // stateful strategies (FedCompress's plateau controller) replay
        // the recorded score history so continuation is exact
        strategy.resume(cfg, &ckpt.scores)?;
        let run_transport = transport.kind().name();
        let run_fleet = cfg.fleet.preset.name();
        if ckpt.transport != run_transport || ckpt.fleet != run_fleet {
            info!(
                "resume mismatch: checkpoint from transport={}/fleet={}, run is {}/{}",
                ckpt.transport, ckpt.fleet, run_transport, run_fleet
            );
            events.push(Event::ResumeMismatch {
                round: start_round,
                ckpt_transport: ckpt.transport.clone(),
                ckpt_fleet: ckpt.fleet.clone(),
                run_transport: run_transport.to_string(),
                run_fleet: run_fleet.to_string(),
            });
        }
    }

    let mut rounds = Vec::with_capacity(cfg.rounds - start_round);
    let workers = match cfg.upload_workers {
        0 => default_workers().max(1),
        w => w,
    };

    for round in start_round..cfg.rounds {
        // fedlint:allow(no-wallclock-state) -- wall_ms is a bench field, excluded from record diffing
        let t0 = std::time::Instant::now();
        let mut round_rng = base.fork(100 + round as u64);
        let ctx = RoundContext {
            round,
            cfg,
            base: &base,
            // warmup: a few dense L_ce-only rounds before the
            // compression machinery engages (paper §1.2; DESIGN.md §3)
            compressing: round >= cfg.warmup_rounds,
            // the downstream is only clustered once SCS has run at least once
            down_compressed: round > cfg.warmup_rounds,
        };
        strategy.round_start(&ctx, &mut model)?;

        // --- dispatch ---------------------------------------------------
        events.push(Event::RoundStart {
            round,
            clusters: model.centroids.active,
        });
        let selected = select_clients(cfg.clients, cfg.participation, &mut round_rng)?;
        let fates = sim.round_fates(round, &selected);
        let down = strategy.encode_download(&ctx, &model)?;
        down.ensure_param_count(p)?;
        let down_framed = framed_down(down.bytes);
        for &k in &selected {
            // the server pushes the dispatch before it can know which
            // clients will fault, so every selected client is ledgered
            ledger.record(round, Direction::Down, down.bytes, down_framed);
            ledger.record_stages(Direction::Down, &down.stage_bytes);
            events.push(Event::Dispatch {
                round,
                client: k,
                bytes: down.bytes,
                compressed: down.bytes < 4 * p,
            });
        }

        // --- client updates via the transport -----------------------------
        let participants: Vec<Participant> = selected
            .iter()
            .zip(&fates)
            .map(|(&client, &fate)| Participant { client, fate })
            .collect();
        let opts = strategy.client_train_opts(&ctx);
        let round_spec = RoundSpec {
            round,
            down: &down,
            centroids: &model.centroids,
            opts,
            compressing: ctx.compressing,
            down_compressed: ctx.down_compressed,
            participants: &participants,
        };
        let env = RoundEnv {
            engine,
            cfg,
            data,
            base: &base,
            encode_workers: workers,
        };
        let results = transport.run_round(&env, &*strategy, &round_spec)?;
        anyhow::ensure!(
            results.len() == participants.len(),
            "transport returned {} results for {} participants",
            results.len(),
            participants.len()
        );

        // --- losses (sim faults + transport faults) -----------------------
        let mut fault_drops = 0usize;
        for (part, res) in participants.iter().zip(&results) {
            if let ClientResult::Dropped(phase) = res {
                fault_drops += 1;
                events.push(Event::Dropout {
                    round,
                    client: part.client,
                    phase: *phase,
                });
            }
        }

        // --- deadline + receive (simulated round clock) -------------------
        let mut uploads = Vec::with_capacity(participants.len());
        let mut ce_sum = 0.0f64;
        let mut up_bytes_round = 0usize;
        let mut max_reporting_s = 0.0f64;
        let mut deadline_drops = 0usize;
        for (part, res) in participants.iter().zip(results) {
            let up = match res {
                ClientResult::Dropped(_) => continue,
                ClientResult::TimedOut { elapsed_s } => {
                    // a *real* straggler cut by the transport's timeout
                    deadline_drops += 1;
                    events.push(Event::Deadline {
                        round,
                        client: part.client,
                        sim_s: elapsed_s,
                    });
                    continue;
                }
                ClientResult::Upload(up) => up,
            };
            up.blob.ensure_param_count(p)?;
            let sim_s = sim.client_time_s(
                part.client,
                down.bytes,
                up.blob.bytes,
                data.labeled[part.client].len(),
                cfg.local_epochs,
                part.fate.slowdown(),
            );
            if sim.clock().over_deadline(sim_s) {
                deadline_drops += 1;
                events.push(Event::Deadline {
                    round,
                    client: part.client,
                    sim_s,
                });
                continue;
            }
            max_reporting_s = max_reporting_s.max(sim_s);
            let up_framed = framed_up(up.blob.bytes);
            ledger.record(round, Direction::Up, up.blob.bytes, up_framed);
            ledger.record_stages(Direction::Up, &up.blob.stage_bytes);
            up_bytes_round += up.blob.bytes;
            events.push(Event::Upload {
                round,
                client: part.client,
                bytes: up.blob.bytes,
                score: up.score,
                mean_ce: up.mean_ce as f64,
            });
            ce_sum += up.mean_ce as f64;
            uploads.push(ClientUpdate {
                client: part.client,
                theta: up.blob.theta,
                mu: up.mu,
                score: up.score,
                n: up.n,
            });
        }
        let dropped = fault_drops + deadline_drops;
        let stragglers = fates.iter().filter(|f| f.is_straggler()).count();
        let round_sim_ms = 1e3 * sim.clock().round_time_s(max_reporting_s, dropped > 0);

        // --- aggregate ----------------------------------------------------
        // survivors only; a fully lost round leaves the model untouched
        let score = if uploads.is_empty() {
            0.0
        } else {
            strategy.aggregate(&ctx, &mut model, &uploads)?
        };
        events.push(Event::Aggregated {
            round,
            clients: uploads.len(),
            score,
        });
        // active count reported for the round (before any growth below)
        let clusters = model.centroids.active;

        // --- strategy server-side work (SCS, controller, ...) -------------
        let env = ServerEnv {
            engine,
            cfg,
            data,
            base: &base,
        };
        if !uploads.is_empty() {
            strategy.post_aggregate(&ctx, &env, &mut model, score, &mut events)?;
        }

        // --- evaluate the deliverable model --------------------------------
        let (accuracy, test_loss) = evaluate(engine, &cfg.dataset, &data.test, &model.theta)?;
        events.push(Event::Evaluated {
            round,
            accuracy,
            loss: test_loss,
        });
        let m = RoundMetrics {
            round,
            accuracy,
            test_loss,
            score,
            // mean over the *survivors* the server actually heard from
            client_mean_ce: ce_sum / uploads.len().max(1) as f64,
            clusters,
            up_bytes: up_bytes_round,
            down_bytes: down.bytes * selected.len(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            round_sim_ms,
            stragglers,
            dropped,
        };
        info!(
            "[{}] {} round {:2}: acc={:.4} loss={:.3} E={:.2} C={} up={}B down={}B \
             sim={:.1}s drop={} strag={} ({:.0} ms)",
            sname,
            cfg.dataset,
            round,
            m.accuracy,
            m.test_loss,
            m.score,
            m.clusters,
            m.up_bytes,
            m.down_bytes,
            m.round_sim_ms / 1e3,
            m.dropped,
            m.stragglers,
            m.wall_ms
        );
        rounds.push(m);
    }

    // --- final deliverable + MCR ------------------------------------------
    let env = ServerEnv {
        engine,
        cfg,
        data,
        base: &base,
    };
    let final_model = strategy.finalize(&env, &model)?;
    let (final_accuracy, _) = evaluate(engine, &cfg.dataset, &data.test, &final_model.theta)?;

    Ok(RunResult {
        strategy: sname,
        dataset: cfg.dataset.clone(),
        rounds,
        final_theta: final_model.theta,
        final_accuracy,
        final_model_bytes: final_model.wire_bytes,
        dense_model_bytes: dense_bytes(p),
        ledger,
        events,
        final_centroids: model.centroids,
    })
}
