//! The federated server (paper Algorithm 1).
//!
//! Per round: dispatch the current model to the selected clients
//! (ledgered), run ClientUpdate on each, FedAvg-aggregate thetas /
//! centroids / scores, then — FedCompress only — SelfCompress on OOD
//! data and grow the cluster count on representation-score plateaus.
//! Evaluation runs on the *deliverable* model (the one that would be
//! dispatched next round), which is what Table 1's accuracy reports.

use anyhow::Result;

use super::aggregate::{fedavg, weighted_mean};
use super::events::{Event, EventLog};
use super::metrics::{RoundMetrics, RunResult};
use super::selection::select_clients;
use crate::baselines::{encode_download, encode_upload};
use crate::client::trainer::{evaluate, train_local};
use crate::clustering::{CentroidState, ClusterController};
use crate::compression::accounting::{CommLedger, Direction};
use crate::compression::codec::{dense_bytes, quantize_and_encode};
use crate::compression::kmeans::kmeans_1d;
use crate::compression::sparsify::magnitude_prune;
use crate::config::{FedConfig, Strategy};
use crate::data::{ood, partition::sigma_to_alpha, partition_dirichlet, synth, Dataset};
use crate::info;
use crate::runtime::literals::{literal_scalar_f32, literal_to_f32, Arg};
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Everything a run needs in memory: client shards, unlabeled shards,
/// test split, server OOD set.
pub struct FederatedData {
    pub labeled: Vec<Dataset>,
    pub unlabeled: Vec<Dataset>,
    pub test: Dataset,
    pub ood: Dataset,
}

/// Materialize the synthetic federated environment for a config.
pub fn build_data(engine: &Engine, cfg: &FedConfig) -> Result<FederatedData> {
    let spec = synth::SynthSpec::for_dataset(&cfg.dataset);
    let domain = engine.manifest.dataset(&cfg.dataset)?.spec.domain.clone();
    let base = Rng::new(cfg.seed);

    let train = synth::generate(&spec, cfg.train_size, cfg.seed, 0);
    let test = synth::generate(&spec, cfg.test_size, cfg.seed, 1);
    let ood = ood::generate(&domain, spec.shape, cfg.ood_size, cfg.seed);

    let mut part_rng = base.fork(1);
    let alpha = sigma_to_alpha(cfg.sigma);
    let min_per = (cfg.unlabeled_per_client + 16).max(24);
    let shards = partition_dirichlet(&train, cfg.clients, alpha, min_per, &mut part_rng);

    let mut labeled = Vec::with_capacity(cfg.clients);
    let mut unlabeled = Vec::with_capacity(cfg.clients);
    for shard in shards {
        let (du, dl) = shard.take(cfg.unlabeled_per_client.min(shard.len() / 3));
        labeled.push(dl);
        unlabeled.push(du);
    }
    Ok(FederatedData {
        labeled,
        unlabeled,
        test,
        ood,
    })
}

/// SelfCompress (Algorithm 1, lines 20-28): distill the aggregated
/// model (teacher) into a re-clustered student on OOD data, then snap.
/// Returns (snapped_student, updated_mu, mean_kl).
fn self_compress(
    engine: &Engine,
    cfg: &FedConfig,
    teacher: &[f32],
    centroids: &mut CentroidState,
    ood_data: &Dataset,
    rng: &mut Rng,
) -> Result<(Vec<f32>, f64)> {
    let ds = &cfg.dataset;
    let batch = engine.manifest.batch;
    let mut student = teacher.to_vec();
    let mut mu = centroids.mu.clone();
    let mask = centroids.mask.clone();
    let mut kl_sum = 0.0f64;
    let mut steps = 0usize;

    for _epoch in 0..cfg.server_epochs {
        for (xs, _ys) in ood_data.epoch_batches(batch, rng) {
            let out = engine.run(
                ds,
                "distill_step",
                &[
                    Arg::F32(&student),
                    Arg::F32(teacher),
                    Arg::F32(&mu),
                    Arg::F32(&mask),
                    Arg::F32(&xs),
                    Arg::Scalar(cfg.lr_server),
                    Arg::Scalar(cfg.beta),
                    Arg::Scalar(cfg.temperature),
                ],
            )?;
            student = literal_to_f32(&out[0])?;
            mu = literal_to_f32(&out[1])?;
            kl_sum += literal_scalar_f32(&out[3])? as f64;
            steps += 1;
        }
    }
    centroids.mu = mu;

    // hard snap to the learned codebook: the downstream wire model
    let codebook = centroids.active_codebook();
    let (_, snapped) = quantize_and_encode(&student, &codebook);
    Ok((snapped, kl_sum / steps.max(1) as f64))
}

/// Run one full federated training experiment.
pub fn run_federated(engine: &Engine, cfg: &FedConfig, strategy: Strategy) -> Result<RunResult> {
    cfg.validate()?;
    let data = build_data(engine, cfg)?;
    run_federated_with_data(engine, cfg, strategy, &data)
}

/// Same, with externally supplied data (lets Table-1 drivers share one
/// environment across the four strategies so deltas are paired).
pub fn run_federated_with_data(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: Strategy,
    data: &FederatedData,
) -> Result<RunResult> {
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let p = engine.manifest.dataset(&cfg.dataset)?.spec.param_count;
    let c_max = engine.manifest.c_max;

    let mut theta = engine.init_theta(&cfg.dataset)?;
    anyhow::ensure!(theta.len() == p, "init theta size mismatch");

    // centroid table: FedZip re-fits per upload; FedCompress learns it
    let mut cents_rng = base.fork(2);
    let c0 = cfg.controller.c_min;
    let mut centroids = CentroidState::init_from_weights(&theta, c0, c_max, &mut cents_rng);
    let mut controller = ClusterController::new(cfg.controller.clone());

    let mut ledger = CommLedger::new();
    let mut events = EventLog::new();
    let mut rounds = Vec::with_capacity(cfg.rounds);
    let use_wc = matches!(
        strategy,
        Strategy::FedCompress | Strategy::FedCompressNoScs
    );

    for round in 0..cfg.rounds {
        let t0 = std::time::Instant::now();
        let mut round_rng = base.fork(100 + round as u64);
        // FedCompress warmup: a few dense L_ce-only rounds before the
        // compression machinery engages (paper §1.2; DESIGN.md §3)
        let compressing = round >= cfg.warmup_rounds;
        // the downstream is only clustered once SCS has run at least once
        let down_compressed = round > cfg.warmup_rounds;

        if strategy == Strategy::FedCompress && round == cfg.warmup_rounds {
            // re-seed the codebook from the *trained* weight
            // distribution, not the init one
            let mut rng = base.fork(60_000 + round as u64);
            let c = centroids.active;
            centroids = CentroidState::init_from_weights(&theta, c, c_max, &mut rng);
        }

        // --- dispatch ---------------------------------------------------
        events.push(Event::RoundStart {
            round,
            clusters: centroids.active,
        });
        let selected = select_clients(cfg.clients, cfg.participation, &mut round_rng);
        let down = encode_download(strategy, down_compressed, &theta, &centroids)?;
        for &k in &selected {
            ledger.record(round, Direction::Down, down.bytes);
            events.push(Event::Dispatch {
                round,
                client: k,
                bytes: down.bytes,
                compressed: down.bytes < 4 * p,
            });
        }

        // --- client updates ----------------------------------------------
        let mut thetas = Vec::with_capacity(selected.len());
        let mut mus = Vec::with_capacity(selected.len());
        let mut scores = Vec::with_capacity(selected.len());
        let mut ns = Vec::with_capacity(selected.len());
        let mut ce_sum = 0.0f64;
        let mut up_bytes_round = 0usize;

        for &k in &selected {
            let mut client_rng = base.fork(10_000 + (round * cfg.clients + k) as u64);
            let outcome = train_local(
                engine,
                cfg,
                &data.labeled[k],
                &data.unlabeled[k],
                &down.theta,
                &centroids,
                use_wc && compressing,
                &mut client_rng,
            )?;
            // client's learned centroids ride along for the upload snap
            let mut client_cents = centroids.clone();
            client_cents.mu = outcome.mu.clone();
            let up = encode_upload(
                strategy,
                cfg,
                &outcome.theta,
                &client_cents,
                compressing,
                &mut client_rng,
            )?;
            ledger.record(round, Direction::Up, up.bytes);
            up_bytes_round += up.bytes;
            events.push(Event::Upload {
                round,
                client: k,
                bytes: up.bytes,
                score: outcome.score,
                mean_ce: outcome.mean_ce as f64,
            });

            thetas.push(up.theta);
            mus.push(outcome.mu);
            scores.push(outcome.score);
            ns.push(outcome.n);
            ce_sum += outcome.mean_ce as f64;
        }

        // --- aggregate (plain FedAvg, unmodified) -------------------------
        theta = fedavg(&thetas, &ns);
        let score = weighted_mean(&scores, &ns);
        events.push(Event::Aggregated {
            round,
            clients: selected.len(),
            score,
        });
        if use_wc {
            centroids.mu = fedavg(&mus, &ns);
        }

        // --- server-side self-compression (FedCompress only) --------------
        if strategy == Strategy::FedCompress && compressing {
            let mut scs_rng = base.fork(50_000 + round as u64);
            if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
                let (pre_acc, _) = evaluate(engine, &cfg.dataset, &data.test, &theta)?;
                crate::debug!("round {round}: pre-SCS aggregated acc={pre_acc:.4}");
            }
            let (snapped, kl) = self_compress(
                engine,
                cfg,
                &theta.clone(),
                &mut centroids,
                &data.ood,
                &mut scs_rng,
            )?;
            crate::debug!("round {round}: SCS mean KL={kl:.4}");
            events.push(Event::SelfCompress {
                round,
                mean_kl: kl,
            });
            theta = snapped;
        }

        // --- dynamic cluster count ----------------------------------------
        let clusters = centroids.active;
        if strategy == Strategy::FedCompress && compressing {
            let next_c = controller.observe(score);
            if next_c > centroids.active {
                events.push(Event::ControllerGrow {
                    round,
                    from: centroids.active,
                    to: next_c,
                });
                centroids.grow_to(next_c);
            }
        }

        // --- evaluate the deliverable model --------------------------------
        let (accuracy, test_loss) = evaluate(engine, &cfg.dataset, &data.test, &theta)?;
        events.push(Event::Evaluated {
            round,
            accuracy,
            loss: test_loss,
        });
        let m = RoundMetrics {
            round,
            accuracy,
            test_loss,
            score,
            client_mean_ce: ce_sum / selected.len() as f64,
            clusters,
            up_bytes: up_bytes_round,
            down_bytes: down.bytes * selected.len(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        info!(
            "[{}] {} round {:2}: acc={:.4} loss={:.3} E={:.2} C={} up={}B down={}B ({:.0} ms)",
            strategy.name(),
            cfg.dataset,
            round,
            m.accuracy,
            m.test_loss,
            m.score,
            m.clusters,
            m.up_bytes,
            m.down_bytes,
            m.wall_ms
        );
        rounds.push(m);
    }

    // --- final deliverable + MCR ------------------------------------------
    let (final_theta, final_model_bytes) = match strategy {
        Strategy::FedAvg => (theta.clone(), dense_bytes(p)),
        Strategy::FedZip => {
            let mut rng = base.fork(9_999);
            let mut pruned = theta.clone();
            magnitude_prune(&mut pruned, cfg.fedzip_keep);
            let (cb, _, _) = kmeans_1d(&pruned, cfg.fedzip_clusters, 25, &mut rng);
            let (enc, q) = quantize_and_encode(&pruned, &cb);
            (q, enc.wire_bytes())
        }
        Strategy::FedCompressNoScs => {
            // final-model-only compression: k-means at the controller's
            // floor C (training never grew it — no score feedback loop)
            let mut rng = base.fork(9_998);
            let (cb, _, _) = kmeans_1d(&theta, cfg.controller.c_min.max(8), 25, &mut rng);
            let (enc, q) = quantize_and_encode(&theta, &cb);
            (q, enc.wire_bytes())
        }
        Strategy::FedCompress => {
            let codebook = centroids.active_codebook();
            let (enc, q) = quantize_and_encode(&theta, &codebook);
            (q, enc.wire_bytes())
        }
    };
    let (final_accuracy, _) = evaluate(engine, &cfg.dataset, &data.test, &final_theta)?;

    Ok(RunResult {
        strategy: strategy.name(),
        dataset: cfg.dataset.clone(),
        rounds,
        final_theta,
        final_accuracy,
        final_model_bytes,
        dense_model_bytes: dense_bytes(p),
        ledger,
        events,
        final_centroids: centroids,
    })
}
