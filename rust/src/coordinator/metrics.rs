//! Round-level metrics and the run-level result record every experiment
//! driver consumes.

use super::events::EventLog;
use crate::clustering::CentroidState;
use crate::compression::accounting::CommLedger;

#[derive(Clone, Debug, PartialEq)]
pub struct RoundMetrics {
    pub round: usize,
    /// test accuracy of the model the server would dispatch next round
    pub accuracy: f64,
    pub test_loss: f64,
    /// aggregated representation-quality score E
    pub score: f64,
    /// mean client validation accuracy proxy (mean client CE)
    pub client_mean_ce: f64,
    /// active cluster count used this round
    pub clusters: usize,
    pub up_bytes: usize,
    pub down_bytes: usize,
    pub wall_ms: f64,
    /// simulated round wall-clock under the configured fleet, ms
    pub round_sim_ms: f64,
    /// selected clients that ran with a straggler slowdown
    pub stragglers: usize,
    /// selected clients lost this round (faults + deadline cuts)
    pub dropped: usize,
}

/// Exact wire size of one [`RoundMetrics`] in a run record.
pub const ROUND_METRICS_BYTES: usize = 80;

impl RoundMetrics {
    /// Fixed-size little-endian image — the per-round unit the run
    /// store persists. Float fields are stored as raw bits, so the
    /// round trip is exact for every value including NaN payloads.
    pub fn to_le_bytes(&self) -> [u8; ROUND_METRICS_BYTES] {
        let mut out = [0u8; ROUND_METRICS_BYTES];
        let mut i = 0;
        let mut put = |bytes: &[u8]| {
            out[i..i + bytes.len()].copy_from_slice(bytes);
            i += bytes.len();
        };
        put(&(self.round as u32).to_le_bytes());
        put(&self.accuracy.to_le_bytes());
        put(&self.test_loss.to_le_bytes());
        put(&self.score.to_le_bytes());
        put(&self.client_mean_ce.to_le_bytes());
        put(&(self.clusters as u32).to_le_bytes());
        put(&(self.up_bytes as u64).to_le_bytes());
        put(&(self.down_bytes as u64).to_le_bytes());
        put(&self.wall_ms.to_le_bytes());
        put(&self.round_sim_ms.to_le_bytes());
        put(&(self.stragglers as u32).to_le_bytes());
        put(&(self.dropped as u32).to_le_bytes());
        debug_assert_eq!(i, ROUND_METRICS_BYTES);
        out
    }

    /// Inverse of [`RoundMetrics::to_le_bytes`]. Infallible: every
    /// 80-byte image decodes (validation against the surrounding
    /// record is the store's job).
    pub fn from_le_bytes(b: &[u8; ROUND_METRICS_BYTES]) -> RoundMetrics {
        let mut i = 0;
        let mut take = |n: usize| {
            let s = &b[i..i + n];
            i += n;
            s
        };
        let u32_of = |s: &[u8]| u32::from_le_bytes(s.try_into().unwrap()) as usize;
        let u64_of = |s: &[u8]| u64::from_le_bytes(s.try_into().unwrap()) as usize;
        let f64_of = |s: &[u8]| f64::from_le_bytes(s.try_into().unwrap());
        RoundMetrics {
            round: u32_of(take(4)),
            accuracy: f64_of(take(8)),
            test_loss: f64_of(take(8)),
            score: f64_of(take(8)),
            client_mean_ce: f64_of(take(8)),
            clusters: u32_of(take(4)),
            up_bytes: u64_of(take(8)),
            down_bytes: u64_of(take(8)),
            wall_ms: f64_of(take(8)),
            round_sim_ms: f64_of(take(8)),
            stragglers: u32_of(take(4)),
            dropped: u32_of(take(4)),
        }
    }
}

/// Total simulated training time of a round sequence, ms. Shared by
/// [`RunResult`] and the store's record views.
pub fn total_sim_ms(rounds: &[RoundMetrics]) -> f64 {
    rounds.iter().map(|r| r.round_sim_ms).sum()
}

/// First round whose evaluated accuracy reached `target`, with the
/// cumulative simulated ms spent up to and including it.
pub fn time_to_accuracy(rounds: &[RoundMetrics], target: f64) -> Option<(usize, f64)> {
    let mut sim_ms = 0.0;
    for r in rounds {
        sim_ms += r.round_sim_ms;
        if r.accuracy >= target {
            return Some((r.round, sim_ms));
        }
    }
    None
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub strategy: &'static str,
    pub dataset: String,
    pub rounds: Vec<RoundMetrics>,
    /// final deliverable model (quantized where the strategy quantizes)
    pub final_theta: Vec<f32>,
    pub final_accuracy: f64,
    /// wire bytes of the final deliverable model
    pub final_model_bytes: usize,
    /// dense f32 bytes of the same model (MCR denominator's numerator)
    pub dense_model_bytes: usize,
    pub ledger: CommLedger,
    /// structured event log of the whole run (observability layer)
    pub events: EventLog,
    /// centroid table at the end of training (drives checkpoints)
    pub final_centroids: CentroidState,
}

impl RunResult {
    /// Model compression ratio versus dense f32 storage.
    pub fn mcr(&self) -> f64 {
        self.dense_model_bytes as f64 / self.final_model_bytes.max(1) as f64
    }

    pub fn total_bytes(&self) -> usize {
        self.ledger.total_bytes()
    }

    /// Total bytes the framed wire carries for this run's ledgered
    /// transfers (payload + per-message protocol overhead). Identical
    /// across transport backends: the in-process transport records the
    /// framing the TCP protocol would have paid.
    pub fn total_framed_bytes(&self) -> usize {
        self.ledger.total_framed_bytes()
    }

    pub fn accuracy_trace(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.accuracy).collect()
    }

    pub fn score_trace(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.score).collect()
    }

    /// Total simulated training time under the configured fleet, ms.
    pub fn total_sim_ms(&self) -> f64 {
        total_sim_ms(&self.rounds)
    }

    /// First round whose evaluated accuracy reached `target`, with the
    /// cumulative simulated ms spent up to and including it.
    pub fn time_to_accuracy(&self, target: f64) -> Option<(usize, f64)> {
        time_to_accuracy(&self.rounds, target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mcr_computation() {
        let r = RunResult {
            strategy: "fedavg",
            dataset: "cifar10".into(),
            rounds: vec![],
            final_theta: vec![],
            final_accuracy: 0.9,
            final_model_bytes: 1000,
            dense_model_bytes: 4000,
            ledger: CommLedger::new(),
            events: EventLog::new(),
            final_centroids: CentroidState {
                mu: vec![0.0; 4],
                mask: vec![1.0; 4],
                c_max: 4,
                active: 4,
            },
        };
        assert!((r.mcr() - 4.0).abs() < 1e-12);
    }

    fn round(round: usize, accuracy: f64, round_sim_ms: f64) -> RoundMetrics {
        RoundMetrics {
            round,
            accuracy,
            test_loss: 1.0,
            score: 1.0,
            client_mean_ce: 1.0,
            clusters: 8,
            up_bytes: 100,
            down_bytes: 100,
            wall_ms: 1.0,
            round_sim_ms,
            stragglers: 0,
            dropped: 0,
        }
    }

    #[test]
    fn sim_time_to_accuracy() {
        let rounds = vec![
            round(0, 0.3, 1000.0),
            round(1, 0.55, 2000.0),
            round(2, 0.5, 500.0),
            round(3, 0.8, 750.0),
        ];
        let r = RunResult {
            strategy: "fedavg",
            dataset: "cifar10".into(),
            rounds,
            final_theta: vec![],
            final_accuracy: 0.8,
            final_model_bytes: 1,
            dense_model_bytes: 4,
            ledger: CommLedger::new(),
            events: EventLog::new(),
            final_centroids: CentroidState {
                mu: vec![0.0; 4],
                mask: vec![1.0; 4],
                c_max: 4,
                active: 4,
            },
        };
        assert_eq!(r.total_sim_ms(), 4250.0);
        // first crossing wins, even if accuracy later dips
        assert_eq!(r.time_to_accuracy(0.5), Some((1, 3000.0)));
        assert_eq!(r.time_to_accuracy(0.8), Some((3, 4250.0)));
        assert_eq!(r.time_to_accuracy(0.9), None);
    }

    /// The store's per-round unit must survive the byte image exactly,
    /// including awkward float payloads.
    #[test]
    fn round_metrics_byte_image_is_bit_exact() {
        let m = RoundMetrics {
            round: 17,
            accuracy: 0.7182818284590452,
            test_loss: 1.25e-3,
            score: 4.062499999999999,
            client_mean_ce: f64::NAN,
            clusters: 24,
            up_bytes: usize::MAX >> 1,
            down_bytes: 123_456_789,
            wall_ms: 0.049999999999999996,
            round_sim_ms: 31.4159,
            stragglers: 3,
            dropped: 2,
        };
        let img = m.to_le_bytes();
        assert_eq!(img.len(), ROUND_METRICS_BYTES);
        let back = RoundMetrics::from_le_bytes(&img);
        // PartialEq would reject the NaN; compare bitwise instead
        assert_eq!(back.round, m.round);
        assert_eq!(back.accuracy.to_bits(), m.accuracy.to_bits());
        assert_eq!(back.test_loss.to_bits(), m.test_loss.to_bits());
        assert_eq!(back.score.to_bits(), m.score.to_bits());
        assert_eq!(back.client_mean_ce.to_bits(), m.client_mean_ce.to_bits());
        assert_eq!(back.clusters, m.clusters);
        assert_eq!(back.up_bytes, m.up_bytes);
        assert_eq!(back.down_bytes, m.down_bytes);
        assert_eq!(back.wall_ms.to_bits(), m.wall_ms.to_bits());
        assert_eq!(back.round_sim_ms.to_bits(), m.round_sim_ms.to_bits());
        assert_eq!(back.stragglers, m.stragglers);
        assert_eq!(back.dropped, m.dropped);
        // and the image itself is a fixpoint
        assert_eq!(back.to_le_bytes(), img);
    }
}
