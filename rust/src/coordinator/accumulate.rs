//! Streaming aggregation: constant-memory folds over client uploads.
//!
//! The historical coordinator buffered every `ClientUpdate` of a round
//! and reduced the full vector at the end — O(fleet × params) memory.
//! This module is the scale-out replacement (ROADMAP item 1): a
//! `fold(upload) / finish()` interface that reduces each upload into a
//! running aggregate as it arrives, so coordinator memory stays flat in
//! fleet size.
//!
//! # Determinism contract: canonicalize, then fold
//!
//! A sequential f64 fold is order-dependent, and a multiplexed
//! transport delivers uploads in arbitrary arrival order. To keep the
//! streaming path bit-identical to the buffered reduce, uploads are
//! *canonicalized before folding*: the round's participants are laid
//! out as slots sorted by client id, and [`StreamAccumulator`] parks an
//! out-of-order upload (bounded by the reorder window, not the fleet)
//! until every earlier slot is resolved, then folds parked uploads in
//! slot order. The fold itself uses one algebra everywhere —
//! `acc[i] += w·x[i]` in f64, divided by the total weight at `finish()`
//! — and the buffered helpers in [`crate::coordinator::aggregate`] are
//! implemented on the same [`WeightedSum`], so "buffered equals
//! streaming, bit for bit" holds by construction and is asserted under
//! arrival-order permutations by `tests/accumulate_stream.rs`.
//!
//! This file is in fedlint's `no-panic-decode` scope: network-fed
//! values flow through here, so everything returns a typed
//! [`AggError`] — no asserts, no indexing, no unchecked division.

use std::fmt;

use crate::coordinator::strategy::ClientUpdate;

/// Typed aggregation failure. Network uploads feed the fold, so every
/// malformed shape is an error value, never a panic (satellite of
/// ISSUE 7; the old `fedavg_slices` asserted and `weighted_mean`
/// yielded NaN on zero total).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AggError {
    /// finish() with no folded uploads (fully-lost round).
    Empty,
    /// finish() with a non-positive total weight (all `n == 0`).
    ZeroWeight,
    /// A folded vector's length disagrees with the first one's.
    Ragged { expected: usize, got: usize },
    /// Buffered helpers: vector count and weight count disagree.
    WeightCount { vectors: usize, weights: usize },
    /// Slot index outside the round's participant range.
    BadSlot { slot: usize, slots: usize },
    /// A slot was resolved twice (duplicate upload or upload-after-loss).
    SlotResolved { slot: usize },
    /// finish() while some slots are still unresolved.
    Unresolved { pending: usize },
}

impl fmt::Display for AggError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AggError::Empty => write!(f, "aggregate of zero uploads"),
            AggError::ZeroWeight => write!(f, "aggregate with non-positive total weight"),
            AggError::Ragged { expected, got } => {
                write!(f, "ragged aggregate: expected {expected} params, got {got}")
            }
            AggError::WeightCount { vectors, weights } => {
                write!(f, "{vectors} vectors but {weights} weights")
            }
            AggError::BadSlot { slot, slots } => {
                write!(f, "slot {slot} out of range for {slots} participants")
            }
            AggError::SlotResolved { slot } => write!(f, "slot {slot} resolved twice"),
            AggError::Unresolved { pending } => {
                write!(f, "finish with {pending} unresolved participant slots")
            }
        }
    }
}

impl std::error::Error for AggError {}

/// Running weighted sum: `acc[i] += w·x[i]` in f64, `acc / Σw` at
/// finish. The single source of arithmetic for both the buffered
/// helpers and the streaming fold — equality between the two paths is
/// by construction, not by test luck.
#[derive(Clone, Debug, Default)]
pub struct WeightedSum {
    acc: Vec<f64>,
    total: f64,
    folds: usize,
}

impl WeightedSum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one weighted vector. The first fold fixes the dimension;
    /// later folds must match it.
    pub fn fold(&mut self, xs: &[f32], w: f64) -> Result<(), AggError> {
        if self.folds == 0 {
            self.acc = vec![0.0; xs.len()];
        } else if xs.len() != self.acc.len() {
            return Err(AggError::Ragged {
                expected: self.acc.len(),
                got: xs.len(),
            });
        }
        // lengths validated above; every kernel backend performs the
        // same two-rounding `acc[i] += w * f64::from(x)` per element
        crate::kernels::axpy_f64(&mut self.acc, xs, w);
        self.total += w;
        self.folds += 1;
        Ok(())
    }

    pub fn folds(&self) -> usize {
        self.folds
    }

    pub fn total(&self) -> f64 {
        self.total
    }

    pub fn finish(self) -> Result<Vec<f32>, AggError> {
        if self.folds == 0 {
            return Err(AggError::Empty);
        }
        if self.total <= 0.0 {
            return Err(AggError::ZeroWeight);
        }
        Ok(self.acc.iter().map(|&a| (a / self.total) as f32).collect())
    }
}

/// What a finished fold hands the strategy: the reduced model, the
/// reduced centroid table, the sample-weighted mean score, and the
/// contributor counts.
#[derive(Clone, Debug, Default)]
pub struct AggOutput {
    pub theta: Vec<f32>,
    pub mu: Vec<f32>,
    pub score: f64,
    /// folds that reached the aggregate (an edge blob counts once)
    pub clients: usize,
    /// Σ n over folded uploads — the aggregate's total sample weight
    pub total_n: usize,
}

/// A strategy's streaming reduction. `fold` consumes one upload;
/// `finish` yields the aggregate. Implementations must be pure in the
/// fold sequence (no wall-clock, no ambient randomness) so the
/// canonicalized replay is deterministic.
pub trait AggFold: Send {
    fn fold(&mut self, up: &ClientUpdate) -> Result<(), AggError>;
    fn finish(self: Box<Self>) -> Result<AggOutput, AggError>;
}

/// Sample-count-weighted FedAvg over theta, centroid table, and score —
/// the unmodified-FedAvg reduction every built-in strategy uses.
#[derive(Clone, Debug, Default)]
pub struct FedAvgFold {
    theta: WeightedSum,
    mu: WeightedSum,
    score_acc: f64,
    clients: usize,
    total_n: usize,
}

impl FedAvgFold {
    pub fn new() -> Self {
        Self::default()
    }
}

impl AggFold for FedAvgFold {
    fn fold(&mut self, up: &ClientUpdate) -> Result<(), AggError> {
        let w = up.n as f64;
        self.theta.fold(&up.theta, w)?;
        self.mu.fold(&up.mu, w)?;
        self.score_acc += w * up.score;
        self.clients += 1;
        self.total_n += up.n;
        Ok(())
    }

    fn finish(self: Box<Self>) -> Result<AggOutput, AggError> {
        let me = *self;
        let total = me.theta.total();
        let theta = me.theta.finish()?;
        // a round of empty centroid tables still reduces to an empty
        // table: mirror theta's weight history rather than re-checking
        let mu = match me.mu.finish() {
            Ok(mu) => mu,
            Err(AggError::Empty) | Err(AggError::ZeroWeight) => Vec::new(),
            Err(e) => return Err(e),
        };
        Ok(AggOutput {
            theta,
            mu,
            score: me.score_acc / total,
            clients: me.clients,
            total_n: me.total_n,
        })
    }
}

enum Slot {
    Pending,
    Parked(Box<ClientUpdate>),
    Lost,
    Folded,
}

/// Park-and-fold reorder buffer over a round's participant slots.
///
/// Slots are the round's participants in canonical order (sorted by
/// client id). Each slot resolves exactly once — to an upload or to a
/// loss — in any order; a greedy cursor folds resolved uploads the
/// moment every earlier slot is resolved. Memory is O(params +
/// reorder-window), not O(fleet): an upload is parked only while an
/// earlier slot is still open, and `peak_parked()` exposes the
/// high-water mark so tests and benches can assert the window stays
/// small.
pub struct StreamAccumulator {
    fold: Box<dyn AggFold>,
    slots: Vec<Slot>,
    cursor: usize,
    parked: usize,
    peak_parked: usize,
    folded: usize,
    lost: usize,
}

impl StreamAccumulator {
    pub fn new(fold: Box<dyn AggFold>, slots: usize) -> Self {
        Self {
            fold,
            slots: (0..slots).map(|_| Slot::Pending).collect(),
            cursor: 0,
            parked: 0,
            peak_parked: 0,
            folded: 0,
            lost: 0,
        }
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// uploads folded into the running aggregate so far
    pub fn folded(&self) -> usize {
        self.folded
    }

    /// slots resolved as lost (dropout / deadline / eviction)
    pub fn lost(&self) -> usize {
        self.lost
    }

    /// high-water mark of uploads held for reordering
    pub fn peak_parked(&self) -> usize {
        self.peak_parked
    }

    /// Resolve a slot with its upload. Folds immediately when the slot
    /// is next in canonical order, parks it otherwise.
    pub fn resolve_upload(&mut self, slot: usize, up: ClientUpdate) -> Result<(), AggError> {
        let slots = self.slots.len();
        let s = self
            .slots
            .get_mut(slot)
            .ok_or(AggError::BadSlot { slot, slots })?;
        if !matches!(s, Slot::Pending) {
            return Err(AggError::SlotResolved { slot });
        }
        *s = Slot::Parked(Box::new(up));
        self.parked += 1;
        self.peak_parked = self.peak_parked.max(self.parked);
        self.advance()
    }

    /// Resolve a slot as lost: the cursor skips it without folding.
    pub fn resolve_lost(&mut self, slot: usize) -> Result<(), AggError> {
        let slots = self.slots.len();
        let s = self
            .slots
            .get_mut(slot)
            .ok_or(AggError::BadSlot { slot, slots })?;
        if !matches!(s, Slot::Pending) {
            return Err(AggError::SlotResolved { slot });
        }
        *s = Slot::Lost;
        self.lost += 1;
        self.advance()
    }

    fn advance(&mut self) -> Result<(), AggError> {
        loop {
            let Some(s) = self.slots.get_mut(self.cursor) else {
                return Ok(());
            };
            match s {
                Slot::Pending => return Ok(()),
                Slot::Lost | Slot::Folded => {}
                Slot::Parked(_) => {
                    if let Slot::Parked(up) = std::mem::replace(s, Slot::Folded) {
                        self.fold.fold(&up)?;
                        self.parked -= 1;
                        self.folded += 1;
                    }
                }
            }
            self.cursor += 1;
        }
    }

    /// Finish the fold. Errors if any slot is still unresolved;
    /// a fully-lost round surfaces as [`AggError::Empty`].
    pub fn finish(self) -> Result<AggOutput, AggError> {
        if self.cursor < self.slots.len() {
            return Err(AggError::Unresolved {
                pending: self.slots.len() - self.folded - self.lost,
            });
        }
        self.fold.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn up(client: usize, theta: &[f32], n: usize, score: f64) -> ClientUpdate {
        ClientUpdate {
            client,
            theta: theta.to_vec(),
            mu: vec![theta[0]; 2],
            score,
            n,
        }
    }

    fn fedavg_acc(slots: usize) -> StreamAccumulator {
        StreamAccumulator::new(Box::new(FedAvgFold::new()), slots)
    }

    #[test]
    fn in_order_fold_matches_weighted_sum() {
        let mut acc = fedavg_acc(2);
        acc.resolve_upload(0, up(0, &[1.0, 2.0], 30, 0.0)).unwrap();
        acc.resolve_upload(1, up(1, &[4.0, 2.0], 10, 10.0)).unwrap();
        let agg = acc.finish().unwrap();
        let mut sum = WeightedSum::new();
        sum.fold(&[1.0, 2.0], 30.0).unwrap();
        sum.fold(&[4.0, 2.0], 10.0).unwrap();
        assert_eq!(agg.theta, sum.finish().unwrap());
        assert_eq!(agg.clients, 2);
        assert_eq!(agg.total_n, 40);
        assert!((agg.score - 2.5).abs() < 1e-12);
    }

    #[test]
    fn out_of_order_arrival_folds_in_slot_order() {
        let ups = [
            up(0, &[1.0], 1, 1.0),
            up(1, &[2.0], 2, 2.0),
            up(2, &[3.0], 3, 3.0),
        ];
        let mut canonical = fedavg_acc(3);
        for (i, u) in ups.iter().enumerate() {
            canonical.resolve_upload(i, u.clone()).unwrap();
        }
        let want = canonical.finish().unwrap();

        let mut shuffled = fedavg_acc(3);
        shuffled.resolve_upload(2, ups[2].clone()).unwrap();
        assert_eq!(shuffled.peak_parked(), 1);
        shuffled.resolve_upload(0, ups[0].clone()).unwrap();
        shuffled.resolve_upload(1, ups[1].clone()).unwrap();
        let got = shuffled.finish().unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got.theta), bits(&want.theta));
        assert_eq!(got.score.to_bits(), want.score.to_bits());
        assert_eq!(shuffled.folded(), 3);
    }

    #[test]
    fn lost_slots_are_skipped_not_folded() {
        let mut acc = fedavg_acc(3);
        acc.resolve_lost(0).unwrap();
        acc.resolve_upload(2, up(2, &[6.0], 2, 0.0)).unwrap();
        acc.resolve_upload(1, up(1, &[3.0], 1, 0.0)).unwrap();
        let agg = acc.finish().unwrap();
        assert_eq!(agg.clients, 2);
        assert_eq!(agg.theta, vec![5.0]); // (3 + 12) / 3
    }

    #[test]
    fn fully_lost_round_is_empty_error() {
        let mut acc = fedavg_acc(2);
        acc.resolve_lost(0).unwrap();
        acc.resolve_lost(1).unwrap();
        assert_eq!(acc.finish().unwrap_err(), AggError::Empty);
    }

    #[test]
    fn zero_total_weight_is_typed_error() {
        let mut acc = fedavg_acc(1);
        acc.resolve_upload(0, up(0, &[1.0], 0, 0.0)).unwrap();
        assert_eq!(acc.finish().unwrap_err(), AggError::ZeroWeight);
    }

    #[test]
    fn ragged_upload_is_typed_error() {
        let mut acc = fedavg_acc(2);
        acc.resolve_upload(0, up(0, &[1.0, 2.0], 1, 0.0)).unwrap();
        let err = acc.resolve_upload(1, up(1, &[1.0], 1, 0.0)).unwrap_err();
        assert_eq!(err, AggError::Ragged { expected: 2, got: 1 });
    }

    #[test]
    fn slot_misuse_is_typed_error() {
        let mut acc = fedavg_acc(2);
        assert_eq!(
            acc.resolve_lost(7).unwrap_err(),
            AggError::BadSlot { slot: 7, slots: 2 }
        );
        acc.resolve_upload(0, up(0, &[1.0], 1, 0.0)).unwrap();
        assert_eq!(
            acc.resolve_upload(0, up(0, &[1.0], 1, 0.0)).unwrap_err(),
            AggError::SlotResolved { slot: 0 }
        );
        assert!(matches!(
            acc.finish().unwrap_err(),
            AggError::Unresolved { pending: 1 }
        ));
    }
}
