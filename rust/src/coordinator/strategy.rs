//! The open strategy plugin API: a `FedStrategy` trait with
//! round-lifecycle hooks, plus the context/state records the hooks see.
//!
//! Hook order per round (driven by `server::run_with_strategy`):
//!
//! 1. [`FedStrategy::round_start`]       — mutate server state before
//!    dispatch (e.g. FedCompress re-seeds its codebook at the warmup
//!    boundary).
//! 2. [`FedStrategy::encode_download`]   — one blob dispatched to every
//!    selected client.
//! 3. [`FedStrategy::client_train_opts`] — options for the local train
//!    step (today: whether the weight-clustering loss is engaged).
//! 4. [`FedStrategy::encode_upload`]     — per client; pure CPU and
//!    `&self`, so the driver fans it out through
//!    `util::threadpool::parallel_map`. MUST NOT touch the engine.
//! 5. [`FedStrategy::make_fold`]         — build the round's streaming
//!    reduction; each decoded upload is folded in as it arrives, in
//!    canonical client-id order (`coordinator::accumulate`), then
//!    [`FedStrategy::aggregate`] commits the finished fold into the
//!    server model; default is byte-identical FedAvg.
//! 6. [`FedStrategy::post_aggregate`]    — server-side work on the
//!    aggregated model (FedCompress: SelfCompress + cluster growth).
//! 7. After the last round, [`FedStrategy::finalize`] produces the
//!    deliverable model + its exact wire size (MCR denominator).
//!
//! Hooks are stateless-by-default: everything a strategy needs per
//! round arrives in [`RoundContext`] (round index, config, the root RNG
//! for deterministic forking, warmup flags) or [`ServerModel`] (theta +
//! centroid table). Strategies that *do* carry state (FedCompress's
//! plateau controller) own it as struct fields; a strategy instance is
//! therefore single-run — build a fresh one per experiment via the
//! `baselines::registry::StrategyRegistry`.
//!
//! Thread-safety contract: `FedStrategy: Send + Sync` so
//! `encode_upload` can run on pool workers. The engine-bearing hooks
//! (`post_aggregate`, `finalize`) receive [`ServerEnv`] instead, which
//! only ever exists on the coordinator thread (the PJRT client is
//! thread-confined by construction).

use anyhow::Result;

use super::accumulate::{AggFold, AggOutput, FedAvgFold};
use super::events::EventLog;
use super::server::FederatedData;
use crate::baselines::wire::WireBlob;
use crate::clustering::CentroidState;
use crate::config::FedConfig;
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Immutable per-round facts shared by every hook. Contains no engine
/// handle so it stays `Sync` and can cross into the encode worker pool.
pub struct RoundContext<'a> {
    pub round: usize,
    pub cfg: &'a FedConfig,
    /// Root RNG of the run; hooks derive deterministic streams via
    /// `base.fork(...)` (never mutate it).
    pub base: &'a Rng,
    /// True once the warmup rounds are over and compression machinery
    /// may engage (`round >= cfg.warmup_rounds`).
    pub compressing: bool,
    /// True once the downstream can be centroid-structured, i.e. SCS
    /// has had a chance to run (`round > cfg.warmup_rounds`).
    pub down_compressed: bool,
}

/// The mutable server-side model state threaded through the run.
pub struct ServerModel {
    pub theta: Vec<f32>,
    pub centroids: CentroidState,
}

/// Engine-bearing environment for coordinator-thread hooks only
/// (`post_aggregate`, `finalize`). Deliberately NOT passed to
/// `encode_upload`: the PJRT client is `!Sync`.
pub struct ServerEnv<'a> {
    pub engine: &'a Engine,
    pub cfg: &'a FedConfig,
    pub data: &'a FederatedData,
    pub base: &'a Rng,
}

/// Options for the client-local training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientTrainOpts {
    /// Train with L_ce + beta * L_wc (the weight-clustering pull).
    pub weight_clustering: bool,
}

/// One client's contribution as the server sees it after decoding the
/// upload: wire-decoded theta plus the sidecar values that ride along.
pub struct ClientUpdate {
    pub client: usize,
    /// decoded upload (post-wire, i.e. quantized where the wire is)
    pub theta: Vec<f32>,
    /// client-learned centroid table (aggregated by WC strategies)
    pub mu: Vec<f32>,
    /// representation-quality score E_k on the client's unlabeled shard
    pub score: f64,
    /// labeled sample count N_k (FedAvg weight)
    pub n: usize,
}

/// Borrowed view of one trained client handed to `encode_upload`.
pub struct UploadInput<'a> {
    pub client: usize,
    /// locally trained dense parameters
    pub theta: &'a [f32],
    /// server centroid table with the client's learned mu swapped in
    pub centroids: &'a CentroidState,
}

/// The deliverable model a strategy ships after training.
pub struct FinalModel {
    pub theta: Vec<f32>,
    /// exact wire size of the shipped model (MCR denominator)
    pub wire_bytes: usize,
}

/// A federated training strategy as a plugin: the round loop is fixed
/// and strategy-agnostic; everything strategy-specific flows through
/// these hooks. See the module docs for the per-round hook order.
pub trait FedStrategy: Send + Sync {
    /// Registry name; also the label on `RunResult` rows.
    fn name(&self) -> &'static str;

    /// Rehydrate plateau/controller state when a run continues from a
    /// checkpoint: `scores` are the original run's per-round aggregated
    /// scores (index = round, exactly `Checkpoint::scores`). Stateless
    /// strategies ignore it; FedCompress replays its cluster
    /// controller so a resumed run continues the uninterrupted one
    /// bit-for-bit.
    fn resume(&mut self, _cfg: &FedConfig, _scores: &[f64]) -> Result<()> {
        Ok(())
    }

    /// Mutate server state before dispatch (codebook re-seeds, ...).
    fn round_start(&mut self, _ctx: &RoundContext<'_>, _model: &mut ServerModel) -> Result<()> {
        Ok(())
    }

    /// Client-side training options for this round.
    fn client_train_opts(&self, _ctx: &RoundContext<'_>) -> ClientTrainOpts {
        ClientTrainOpts::default()
    }

    /// Encode the server dispatch (one blob, sent to every selected
    /// client).
    fn encode_download(&self, ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob>;

    /// Encode one client's upload. Runs on pool workers (`&self`, no
    /// engine); `rng` is the client's deterministic stream, positioned
    /// exactly where local training left it.
    fn encode_upload(
        &self,
        ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob>;

    /// Build this round's streaming reduction. The round loop folds
    /// each decoded upload into it in canonical (client-id) order, as
    /// the upload arrives — constant memory in fleet size. Default:
    /// sample-count FedAvg over theta, centroid table, and score.
    fn make_fold(&self, _ctx: &RoundContext<'_>) -> Box<dyn AggFold> {
        Box::new(FedAvgFold::new())
    }

    /// Commit a finished fold into the server model; returns the
    /// aggregated representation score E. Default: install the reduced
    /// theta and leave the server centroid table alone (the paper's
    /// unmodified aggregation).
    fn aggregate(
        &mut self,
        _ctx: &RoundContext<'_>,
        model: &mut ServerModel,
        agg: AggOutput,
    ) -> Result<f64> {
        model.theta = agg.theta;
        Ok(agg.score)
    }

    /// Server-side work on the aggregated model (SelfCompress, cluster
    /// controller, ...). Runs on the coordinator thread with engine
    /// access; may push events.
    fn post_aggregate(
        &mut self,
        _ctx: &RoundContext<'_>,
        _env: &ServerEnv<'_>,
        _model: &mut ServerModel,
        _score: f64,
        _events: &mut EventLog,
    ) -> Result<()> {
        Ok(())
    }

    /// Produce the final deliverable model and its exact wire size.
    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn model() -> ServerModel {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let centroids = CentroidState::init_from_weights(&theta, 4, 8, &mut rng);
        ServerModel { theta, centroids }
    }

    fn update(client: usize, v: f32, n: usize) -> ClientUpdate {
        ClientUpdate {
            client,
            theta: vec![v; 64],
            mu: vec![v; 8],
            score: v as f64,
            n,
        }
    }

    fn run_fold(ups: &[ClientUpdate]) -> AggOutput {
        let mut fold: Box<dyn AggFold> = Box::new(FedAvgFold::new());
        for u in ups {
            fold.fold(u).unwrap();
        }
        fold.finish().unwrap()
    }

    #[test]
    fn default_aggregation_is_weighted_fedavg() {
        let mut m = model();
        let agg = run_fold(&[update(0, 0.0, 30), update(1, 10.0, 10)]);
        let score = agg.score;
        m.theta = agg.theta;
        assert!((m.theta[0] - 2.5).abs() < 1e-6);
        assert!((score - 2.5).abs() < 1e-9);
    }

    #[test]
    fn centroid_aggregation_tracks_weights() {
        let mut m = model();
        let agg = run_fold(&[update(0, 1.0, 1), update(1, 3.0, 3)]);
        m.centroids.mu = agg.mu;
        assert!((m.centroids.mu[0] - 2.5).abs() < 1e-6);
    }
}
