//! FedAvg aggregation (McMahan 2017): sample-count-weighted averaging of
//! flat parameter vectors — deliberately unmodified, which is the point
//! of FedCompress ("no modifications to the underlying aggregation").
//! The same weighting aggregates centroid tables and representation
//! scores (paper Algorithm 1, line 7).
//!
//! These are the *buffered* helpers; they are thin wrappers over
//! [`WeightedSum`], the same running fold the streaming path
//! (`coordinator::accumulate`) uses, so the two reduce bit-identically
//! by construction. Inputs can come straight off the network, so every
//! malformed shape — ragged vectors, zero uploads, zero total weight —
//! is a typed [`AggError`], never a panic or a silent NaN.

use crate::coordinator::accumulate::{AggError, WeightedSum};

/// Weighted average of flat vectors. `weights[i]` is client i's sample
/// count N_k; vectors must agree in length.
pub fn fedavg(vectors: &[Vec<f32>], weights: &[usize]) -> Result<Vec<f32>, AggError> {
    let refs: Vec<&[f32]> = vectors.iter().map(|v| v.as_slice()).collect();
    fedavg_slices(&refs, weights)
}

/// Borrow-friendly form of [`fedavg`] (strategy plugins aggregate
/// uploads without cloning each client vector).
pub fn fedavg_slices(vectors: &[&[f32]], weights: &[usize]) -> Result<Vec<f32>, AggError> {
    if vectors.len() != weights.len() {
        return Err(AggError::WeightCount {
            vectors: vectors.len(),
            weights: weights.len(),
        });
    }
    let mut sum = WeightedSum::new();
    for (v, &w) in vectors.iter().zip(weights) {
        sum.fold(v, w as f64)?;
    }
    sum.finish()
}

/// Weighted scalar average (for the representation score E).
pub fn weighted_mean(values: &[f64], weights: &[usize]) -> Result<f64, AggError> {
    if values.len() != weights.len() {
        return Err(AggError::WeightCount {
            vectors: values.len(),
            weights: weights.len(),
        });
    }
    if values.is_empty() {
        return Err(AggError::Empty);
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&v, &w) in values.iter().zip(weights) {
        num += v * w as f64;
        den += w as f64;
    }
    if den <= 0.0 {
        return Err(AggError::ZeroWeight);
    }
    Ok(num / den)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_weights_is_plain_mean() {
        let v = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let out = fedavg(&v, &[10, 10]).unwrap();
        assert_eq!(out, vec![2.0, 3.0]);
    }

    #[test]
    fn weighting_respects_sample_counts() {
        let v = vec![vec![0.0f32], vec![10.0]];
        let out = fedavg(&v, &[30, 10]).unwrap();
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn single_client_identity() {
        let v = vec![vec![1.5f32, -2.5, 0.0]];
        assert_eq!(fedavg(&v, &[7]).unwrap(), v[0]);
    }

    #[test]
    fn convexity_property() {
        // aggregate lies within [min, max] per coordinate
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let vs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..40).map(|_| rng.normal()).collect())
            .collect();
        let ws = [3usize, 9, 1, 5, 2];
        let agg = fedavg(&vs, &ws).unwrap();
        for j in 0..40 {
            let lo = vs.iter().map(|v| v[j]).fold(f32::MAX, f32::min);
            let hi = vs.iter().map(|v| v[j]).fold(f32::MIN, f32::max);
            assert!(agg[j] >= lo - 1e-6 && agg[j] <= hi + 1e-6);
        }
    }

    #[test]
    fn weighted_mean_scalar() {
        assert!((weighted_mean(&[1.0, 3.0], &[1, 3]).unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ragged_vectors_are_typed_errors() {
        let err = fedavg(&[vec![1.0], vec![1.0, 2.0]], &[1, 1]).unwrap_err();
        assert_eq!(err, AggError::Ragged { expected: 1, got: 2 });
    }

    #[test]
    fn empty_and_zero_weight_are_typed_errors() {
        assert_eq!(fedavg(&[], &[]).unwrap_err(), AggError::Empty);
        let err = fedavg(&[vec![1.0], vec![2.0]], &[0, 0]).unwrap_err();
        assert_eq!(err, AggError::ZeroWeight);
        assert_eq!(weighted_mean(&[], &[]).unwrap_err(), AggError::Empty);
        assert_eq!(weighted_mean(&[1.0], &[0]).unwrap_err(), AggError::ZeroWeight);
        let err = weighted_mean(&[1.0], &[1, 2]).unwrap_err();
        assert_eq!(err, AggError::WeightCount { vectors: 1, weights: 2 });
    }
}
