//! FedAvg aggregation (McMahan 2017): sample-count-weighted averaging of
//! flat parameter vectors — deliberately unmodified, which is the point
//! of FedCompress ("no modifications to the underlying aggregation").
//! The same weighting aggregates centroid tables and representation
//! scores (paper Algorithm 1, line 7).

/// Weighted average of flat vectors. `weights[i]` is client i's sample
/// count N_k; vectors must agree in length.
pub fn fedavg(vectors: &[Vec<f32>], weights: &[usize]) -> Vec<f32> {
    let refs: Vec<&[f32]> = vectors.iter().map(|v| v.as_slice()).collect();
    fedavg_slices(&refs, weights)
}

/// Borrow-friendly form of [`fedavg`] (strategy plugins aggregate
/// uploads without cloning each client vector).
pub fn fedavg_slices(vectors: &[&[f32]], weights: &[usize]) -> Vec<f32> {
    assert!(!vectors.is_empty());
    assert_eq!(vectors.len(), weights.len());
    let n = vectors[0].len();
    let total: f64 = weights.iter().map(|&w| w as f64).sum();
    assert!(total > 0.0, "all clients empty");
    let mut out = vec![0.0f64; n];
    for (v, &w) in vectors.iter().zip(weights) {
        assert_eq!(v.len(), n, "ragged client vectors");
        let coef = w as f64 / total;
        for (o, &x) in out.iter_mut().zip(v.iter()) {
            *o += coef * x as f64;
        }
    }
    out.into_iter().map(|x| x as f32).collect()
}

/// Weighted scalar average (for the representation score E).
pub fn weighted_mean(values: &[f64], weights: &[usize]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let total: f64 = weights.iter().map(|&w| w as f64).sum();
    values
        .iter()
        .zip(weights)
        .map(|(&v, &w)| v * w as f64 / total)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_weights_is_plain_mean() {
        let v = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let out = fedavg(&v, &[10, 10]);
        assert_eq!(out, vec![2.0, 3.0]);
    }

    #[test]
    fn weighting_respects_sample_counts() {
        let v = vec![vec![0.0f32], vec![10.0]];
        let out = fedavg(&v, &[30, 10]);
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn single_client_identity() {
        let v = vec![vec![1.5f32, -2.5, 0.0]];
        assert_eq!(fedavg(&v, &[7]), v[0]);
    }

    #[test]
    fn convexity_property() {
        // aggregate lies within [min, max] per coordinate
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let vs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..40).map(|_| rng.normal()).collect())
            .collect();
        let ws = [3usize, 9, 1, 5, 2];
        let agg = fedavg(&vs, &ws);
        for j in 0..40 {
            let lo = vs.iter().map(|v| v[j]).fold(f32::MAX, f32::min);
            let hi = vs.iter().map(|v| v[j]).fold(f32::MIN, f32::max);
            assert!(agg[j] >= lo - 1e-6 && agg[j] <= hi + 1e-6);
        }
    }

    #[test]
    fn weighted_mean_scalar() {
        assert!((weighted_mean(&[1.0, 3.0], &[1, 3]) - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn ragged_vectors_panic() {
        fedavg(&[vec![1.0], vec![1.0, 2.0]], &[1, 1]);
    }
}
