//! Per-round client selection (paper: "randomly select K clients").

use std::fmt;

use crate::util::rng::Rng;

/// Typed invariant violation: a round cannot select from an empty
/// client pool. Debug builds assert; release builds surface the typed
/// error (the same contract as `WireBlob::ensure_param_count`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EmptyClientPool;

impl fmt::Display for EmptyClientPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cannot select clients from an empty pool (m = 0)")
    }
}

impl std::error::Error for EmptyClientPool {}

/// Select ceil(participation * m) distinct clients for a round.
pub fn select_clients(
    m: usize,
    participation: f64,
    rng: &mut Rng,
) -> Result<Vec<usize>, EmptyClientPool> {
    debug_assert!(m > 0, "cannot select clients from an empty pool");
    if m == 0 {
        return Err(EmptyClientPool);
    }
    let k = ((m as f64 * participation).ceil() as usize).clamp(1, m);
    Ok(rng.choose(m, k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_selects_everyone() {
        let mut rng = Rng::new(1);
        let s = select_clients(20, 1.0, &mut rng).unwrap();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn partial_participation_counts() {
        let mut rng = Rng::new(2);
        let s = select_clients(20, 0.25, &mut rng).unwrap();
        assert_eq!(s.len(), 5);
        // distinctness: sort first — dedup alone only removes *adjacent*
        // duplicates, which an unsorted selection could hide
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 5);
        // and every pick is a valid client id
        assert!(s.iter().all(|&k| k < 20));
    }

    #[test]
    fn at_least_one_client() {
        let mut rng = Rng::new(3);
        assert_eq!(select_clients(10, 0.01, &mut rng).unwrap().len(), 1);
    }

    #[test]
    fn varies_across_rounds() {
        let mut rng = Rng::new(4);
        let a = select_clients(50, 0.2, &mut rng).unwrap();
        let b = select_clients(50, 0.2, &mut rng).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn empty_pool_is_a_typed_error() {
        let mut rng = Rng::new(5);
        if cfg!(debug_assertions) {
            // debug builds assert loudly
            let r = std::panic::catch_unwind(move || select_clients(0, 1.0, &mut rng));
            assert!(r.is_err(), "debug_assert should fire on m = 0");
        } else {
            // release builds surface the typed error
            let e = select_clients(0, 1.0, &mut rng).unwrap_err();
            assert_eq!(e, EmptyClientPool);
            assert!(e.to_string().contains("empty pool"));
        }
    }
}
