//! Per-round client selection (paper: "randomly select K clients").

use crate::util::rng::Rng;

/// Select ceil(participation * m) distinct clients for a round.
pub fn select_clients(m: usize, participation: f64, rng: &mut Rng) -> Vec<usize> {
    assert!(m > 0);
    let k = ((m as f64 * participation).ceil() as usize).clamp(1, m);
    rng.choose(m, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_selects_everyone() {
        let mut rng = Rng::new(1);
        let s = select_clients(20, 1.0, &mut rng);
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn partial_participation_counts() {
        let mut rng = Rng::new(2);
        let s = select_clients(20, 0.25, &mut rng);
        assert_eq!(s.len(), 5);
        let mut d = s.clone();
        d.dedup();
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn at_least_one_client() {
        let mut rng = Rng::new(3);
        assert_eq!(select_clients(10, 0.01, &mut rng).len(), 1);
    }

    #[test]
    fn varies_across_rounds() {
        let mut rng = Rng::new(4);
        let a = select_clients(50, 0.2, &mut rng);
        let b = select_clients(50, 0.2, &mut rng);
        assert_ne!(a, b);
    }
}
