//! Run checkpointing: persist/restore (round, theta, centroids,
//! controller score history) so long federated runs survive restarts —
//! a framework necessity the paper's Flower setup gets for free.
//!
//! Binary format v2 (little-endian):
//!   magic "FCCK" | u32 version | u32 round | u32 P | u32 C_max |
//!   u32 active | f32 theta[P] | f32 mu[C_max] | u32 n_scores |
//!   f64 scores[n] | str transport | str fleet |
//!   u64 checksum (FNV-1a over all preceding bytes)
//! where `str` is u16 length + utf-8 bytes. The transport kind
//! (`inproc`/`tcp`) and fleet preset record the environment the run
//! was produced under; resuming under a different one emits
//! `Event::ResumeMismatch`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::clustering::CentroidState;
use crate::util::hash::fnv1a64;

const MAGIC: &[u8; 4] = b"FCCK";
const VERSION: u32 = 2;

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: usize,
    pub theta: Vec<f32>,
    pub mu: Vec<f32>,
    pub active: usize,
    pub scores: Vec<f64>,
    /// transport kind the run used (`TransportKind::name()`)
    pub transport: String,
    /// fleet preset the run used (`FleetPreset::name()`)
    pub fleet: String,
}

impl Checkpoint {
    pub fn from_state(
        round: usize,
        theta: &[f32],
        centroids: &CentroidState,
        scores: &[f64],
        transport: &str,
        fleet: &str,
    ) -> Checkpoint {
        Checkpoint {
            round,
            theta: theta.to_vec(),
            mu: centroids.mu.clone(),
            active: centroids.active,
            scores: scores.to_vec(),
            transport: transport.to_string(),
            fleet: fleet.to_string(),
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + 4 * (self.theta.len() + self.mu.len()));
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.round as u32).to_le_bytes());
        out.extend_from_slice(&(self.theta.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.mu.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.active as u32).to_le_bytes());
        for v in &self.theta {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.mu {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.scores.len() as u32).to_le_bytes());
        for v in &self.scores {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for s in [&self.transport, &self.fleet] {
            out.extend_from_slice(&(s.len() as u16).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        let ck = fnv1a64(&out);
        out.extend_from_slice(&ck.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 8 + 16 + 8 {
            bail!("checkpoint too short");
        }
        let (body, ck_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(ck_bytes.try_into()?);
        if fnv1a64(body) != stored {
            bail!("checkpoint checksum mismatch (corrupt file)");
        }
        let mut i = 0usize;
        let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
            if *i + n > body.len() {
                bail!("truncated checkpoint");
            }
            let s = &body[*i..*i + n];
            *i += n;
            Ok(s)
        };
        if take(&mut i, 4)? != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = u32::from_le_bytes(take(&mut i, 4)?.try_into()?);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let round = u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
        let p = u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
        let c_max = u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
        let active = u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
        if active > c_max {
            bail!("active > c_max in checkpoint");
        }
        let mut theta = Vec::with_capacity(p);
        for _ in 0..p {
            theta.push(f32::from_le_bytes(take(&mut i, 4)?.try_into()?));
        }
        let mut mu = Vec::with_capacity(c_max);
        for _ in 0..c_max {
            mu.push(f32::from_le_bytes(take(&mut i, 4)?.try_into()?));
        }
        let n = u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
        let mut scores = Vec::with_capacity(n);
        for _ in 0..n {
            scores.push(f64::from_le_bytes(take(&mut i, 8)?.try_into()?));
        }
        let mut read_str = |i: &mut usize| -> Result<String> {
            let len = u16::from_le_bytes(take(i, 2)?.try_into()?) as usize;
            Ok(String::from_utf8(take(i, len)?.to_vec())?)
        };
        let transport = read_str(&mut i)?;
        let fleet = read_str(&mut i)?;
        Ok(Checkpoint {
            round,
            theta,
            mu,
            active,
            scores,
            transport,
            fleet,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        // a checkpoint path like runs/exp7/final.ckpt should not force
        // callers to pre-create the directory tree
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating checkpoint dir {parent:?}"))?;
            }
        }
        // atomic-ish: write sibling then rename
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_bytes())
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, path).with_context(|| format!("renaming to {path:?}"))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Checkpoint::from_bytes(&bytes)
    }

    /// Restore a CentroidState (mask rebuilt from `active`).
    pub fn centroid_state(&self) -> CentroidState {
        let c_max = self.mu.len();
        let mut mask = vec![0.0f32; c_max];
        for m in mask.iter_mut().take(self.active) {
            *m = 1.0;
        }
        CentroidState {
            mu: self.mu.clone(),
            mask,
            c_max,
            active: self.active,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn demo() -> Checkpoint {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..500).map(|_| rng.normal()).collect();
        let cents = CentroidState::init_from_weights(&theta, 12, 32, &mut rng);
        Checkpoint::from_state(7, &theta, &cents, &[1.0, 2.5, 3.25], "inproc", "ideal")
    }

    #[test]
    fn roundtrip_bytes() {
        let c = demo();
        let d = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn roundtrip_file() {
        let c = demo();
        let dir = std::env::temp_dir().join("fedcompress_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        c.save(&path).unwrap();
        let d = Checkpoint::load(&path).unwrap();
        assert_eq!(c, d);
    }

    /// `save` must create missing parent directories instead of
    /// erroring — long runs checkpoint into per-experiment subtrees
    /// that usually do not exist yet.
    #[test]
    fn save_creates_missing_parent_directories() {
        let c = demo();
        let root = std::env::temp_dir().join("fedcompress_ckpt_mkdir_test");
        let _ = std::fs::remove_dir_all(&root);
        let path = root.join("deep/nested/dirs/run.ckpt");
        assert!(!path.parent().unwrap().exists());
        c.save(&path).unwrap();
        let d = Checkpoint::load(&path).unwrap();
        assert_eq!(c, d);
        // a bare filename (no parent component) still saves fine
        let cwd_file = Path::new("fedcompress_ckpt_bare_test.ckpt");
        c.save(cwd_file).unwrap();
        assert_eq!(Checkpoint::load(cwd_file).unwrap(), c);
        let _ = std::fs::remove_file(cwd_file);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn corruption_is_detected() {
        let c = demo();
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        let mut short = c.to_bytes();
        short.truncate(20);
        assert!(Checkpoint::from_bytes(&short).is_err());
    }

    #[test]
    fn centroid_state_restores_mask() {
        let c = demo();
        let s = c.centroid_state();
        assert_eq!(s.active, 12);
        assert_eq!(s.mask.iter().filter(|&&m| m == 1.0).count(), 12);
        assert_eq!(s.mu, c.mu);
    }

    /// The resume contract end-to-end: everything a restarted run needs
    /// — round cursor, theta, the full centroid state (mu, mask,
    /// active), controller score history — survives save -> load
    /// bit-exactly, so resuming from the file is equivalent to never
    /// having stopped.
    #[test]
    fn save_load_resume_equivalence() {
        let mut rng = Rng::new(9);
        let theta: Vec<f32> = (0..800).map(|_| rng.normal() * 0.3).collect();
        let mut cents = CentroidState::init_from_weights(&theta, 6, 24, &mut rng);
        cents.grow_to(10); // a mid-run controller growth, mask half-set
        let scores = vec![1.5, 2.25, 2.25, 3.0];

        let dir = std::env::temp_dir().join("fedcompress_ckpt_resume_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume.ckpt");
        Checkpoint::from_state(4, &theta, &cents, &scores, "tcp", "mobile")
            .save(&path)
            .unwrap();

        let resumed = Checkpoint::load(&path).unwrap();
        assert_eq!(resumed.round, 4);
        assert_eq!(resumed.theta, theta);
        assert_eq!(resumed.scores, scores);
        // the environment the run was produced under survives the file
        assert_eq!(resumed.transport, "tcp");
        assert_eq!(resumed.fleet, "mobile");
        let rc = resumed.centroid_state();
        assert_eq!(rc.mu, cents.mu);
        assert_eq!(rc.mask, cents.mask);
        assert_eq!(rc.active, cents.active);
        assert_eq!(rc.c_max, cents.c_max);

        // saving the resumed state reproduces the file byte-for-byte
        let again = Checkpoint::from_state(4, &theta, &cents, &scores, "tcp", "mobile");
        assert_eq!(resumed.to_bytes(), again.to_bytes());
    }

    #[test]
    fn unsupported_version_is_rejected() {
        let c = demo();
        let mut bytes = c.to_bytes();
        // bump the version field (bytes 4..8) and re-stamp the checksum
        bytes[4] = 99;
        let body_len = bytes.len() - 8;
        let ck = fnv1a64(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&ck.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn empty_scores_and_zero_round_round_trip() {
        let mut rng = Rng::new(2);
        let theta: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let cents = CentroidState::init_from_weights(&theta, 4, 8, &mut rng);
        let c = Checkpoint::from_state(0, &theta, &cents, &[], "inproc", "ideal");
        let d = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, d);
        assert!(d.scores.is_empty());
    }

    /// v1 files (no environment metadata) are refused loudly rather
    /// than silently defaulted — the resume contract depends on the
    /// recorded transport/fleet being real.
    #[test]
    fn version_one_files_are_rejected() {
        let c = demo();
        let mut bytes = c.to_bytes();
        bytes[4] = 1;
        let body_len = bytes.len() - 8;
        let ck = fnv1a64(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&ck.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
    }
}
