//! Layer-3 coordinator — the paper's system contribution.
//!
//! `server` drives Algorithm 1: dispatch, parallel-in-spirit client
//! updates, FedAvg aggregation, server-side self-compression, dynamic
//! cluster control, and the byte-exact communication ledger.

pub mod aggregate;
pub mod checkpoint;
pub mod events;
pub mod metrics;
pub mod selection;
pub mod server;

pub use metrics::{RoundMetrics, RunResult};
pub use server::run_federated;
