//! Layer-3 coordinator — the paper's system contribution.
//!
//! `server` drives Algorithm 1 as a strategy-agnostic round loop:
//! dispatch, client updates (upload encoding fanned out over the worker
//! pool), aggregation, strategy server-side hooks, and the byte-exact
//! communication ledger. Per-strategy behavior lives behind the
//! `strategy::FedStrategy` plugin trait, resolved by name through
//! `baselines::registry::StrategyRegistry`.

pub mod accumulate;
pub mod aggregate;
pub mod checkpoint;
pub mod events;
pub mod metrics;
pub mod selection;
pub mod server;
pub mod strategy;

pub use accumulate::{AggError, AggFold, AggOutput, FedAvgFold, StreamAccumulator};
pub use metrics::{RoundMetrics, RunResult};
pub use server::{
    run_federated, run_federated_with_data, run_with_strategy, run_with_strategy_opts,
    run_with_strategy_sink, EdgeCutMember, EdgeMember, EdgePartial, RoundIngest, RoundIntake,
};
pub use strategy::{
    ClientTrainOpts, ClientUpdate, FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel,
    UploadInput,
};
