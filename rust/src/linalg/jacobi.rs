//! Cyclic Jacobi eigenvalue iteration for symmetric matrices.
//!
//! Classic two-sided rotations; quadratic convergence once off-diagonal
//! mass is small. Our matrices are Gram matrices of embeddings
//! (d <= 64), where full sweeps cost microseconds — no need for
//! tridiagonalization.

use super::matrix::Matrix;

/// Eigenvalues of a symmetric matrix (unordered).
pub fn symmetric_eigenvalues(m: &Matrix) -> Vec<f64> {
    assert_eq!(m.rows(), m.cols(), "matrix must be square");
    let n = m.rows();
    if n == 0 {
        return Vec::new();
    }
    let mut a = m.clone();

    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        let scale = a.frobenius_norm().max(1e-300);
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                // rotation angle: tan(2θ) = 2 a_pq / (a_pp - a_qq)
                let theta = (aqq - app) / (2.0 * apq);
                let t = {
                    let sign = if theta >= 0.0 { 1.0 } else { -1.0 };
                    sign / (theta.abs() + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // A <- J^T A J, touching rows/cols p and q
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
            }
        }
    }
    (0..n).map(|i| a.get(i, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sorted(mut v: Vec<f64>) -> Vec<f64> {
        v.sort_by(f64::total_cmp);
        v
    }

    #[test]
    fn diagonal_matrix() {
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 0, 5.0);
        m.set(1, 1, -2.0);
        m.set(2, 2, 0.5);
        let e = sorted(symmetric_eigenvalues(&m));
        assert!((e[0] + 2.0).abs() < 1e-12);
        assert!((e[1] - 0.5).abs() < 1e-12);
        assert!((e[2] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2, 1], [1, 2]] -> eigenvalues 1 and 3
        let m = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = sorted(symmetric_eigenvalues(&m));
        assert!((e[0] - 1.0).abs() < 1e-12);
        assert!((e[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_frobenius_invariants() {
        // property: sum(eig) == trace, sum(eig^2) == ||A||_F^2
        let mut rng = Rng::new(31);
        for n in [2usize, 5, 16, 33] {
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in i..n {
                    let v = rng.normal() as f64;
                    m.set(i, j, v);
                    m.set(j, i, v);
                }
            }
            let e = symmetric_eigenvalues(&m);
            let trace: f64 = (0..n).map(|i| m.get(i, i)).sum();
            let fro2: f64 = m.frobenius_norm().powi(2);
            let es: f64 = e.iter().sum();
            let es2: f64 = e.iter().map(|x| x * x).sum();
            assert!((es - trace).abs() < 1e-9 * (1.0 + trace.abs()), "n={n}");
            assert!((es2 - fro2).abs() < 1e-9 * (1.0 + fro2), "n={n}");
        }
    }

    #[test]
    fn psd_gram_eigenvalues_nonnegative() {
        let mut rng = Rng::new(77);
        let mut a = Matrix::zeros(20, 8);
        for i in 0..20 {
            for j in 0..8 {
                a.set(i, j, rng.normal() as f64);
            }
        }
        let e = symmetric_eigenvalues(&a.gram());
        for &x in &e {
            assert!(x > -1e-9, "{x}");
        }
    }

    #[test]
    fn empty_and_single() {
        assert!(symmetric_eigenvalues(&Matrix::zeros(0, 0)).is_empty());
        let mut m = Matrix::zeros(1, 1);
        m.set(0, 0, 4.2);
        assert_eq!(symmetric_eigenvalues(&m), vec![4.2]);
    }
}
