//! Dense linear algebra substrate (no LAPACK in the vendored set).
//!
//! Provides exactly what the representation-quality score needs:
//! a column-major dense matrix, symmetric eigenvalues via cyclic
//! Jacobi, and singular values of a tall matrix through its Gram
//! matrix (sigma_j = sqrt(eig_j(Z^T Z))) — embeddings are N x d with
//! d <= 64, so the Gram route is both exact enough and fast.

pub mod jacobi;
pub mod matrix;

pub use jacobi::symmetric_eigenvalues;
pub use matrix::Matrix;

/// Singular values of `a` (rows x cols, rows >= 1), descending.
///
/// Computed as sqrt of the eigenvalues of the Gram matrix over the
/// smaller dimension; negative eigenvalues from roundoff clamp to 0.
pub fn singular_values(a: &Matrix) -> Vec<f64> {
    let gram = if a.rows() >= a.cols() {
        a.gram() // A^T A : cols x cols
    } else {
        a.gram_t() // A A^T : rows x rows
    };
    let mut eig = symmetric_eigenvalues(&gram);
    // total_cmp: a NaN eigenvalue (degenerate embedding batch) must not
    // panic the coordinator mid-run
    eig.sort_by(|x, y| y.total_cmp(x));
    eig.into_iter().map(|l| l.max(0.0).sqrt()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singular_values_of_diagonal() {
        // A = diag(3, 2, 1) embedded in 5x3
        let mut a = Matrix::zeros(5, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 1.0);
        let s = singular_values(&a);
        assert_eq!(s.len(), 3);
        assert!((s[0] - 3.0).abs() < 1e-10);
        assert!((s[1] - 2.0).abs() < 1e-10);
        assert!((s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn singular_values_orthogonal_invariance() {
        // rotating rows leaves singular values unchanged
        let a = Matrix::from_rows(&[
            &[1.0, 2.0],
            &[3.0, 4.0],
            &[5.0, 6.0],
        ]);
        let s = singular_values(&a);
        // known singular values of this classic matrix
        assert!((s[0] - 9.52551809).abs() < 1e-6);
        assert!((s[1] - 0.51430058).abs() < 1e-6);
    }

    #[test]
    fn wide_matrix_uses_small_gram() {
        let a = Matrix::from_rows(&[&[1.0, 0.0, 0.0, 2.0], &[0.0, 3.0, 0.0, 0.0]]);
        let s = singular_values(&a);
        assert_eq!(s.len(), 2);
        assert!((s[0] - 3.0).abs() < 1e-10);
        assert!((s[1] - 5.0f64.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn rank_deficient_has_zero_sigma() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0], &[3.0, 6.0]]);
        let s = singular_values(&a);
        assert!(s[1].abs() < 1e-9, "{s:?}");
    }
}
