//! Row-major dense f64 matrix with just the operations the score path
//! needs (Gram products, symmetric access). Deliberately small.

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>, // row-major
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Matrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// Build from a flat row-major f32 buffer (embeddings come off the
    /// PJRT runtime as f32).
    pub fn from_f32_rows(data: &[f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// A^T A (cols x cols).
    pub fn gram(&self) -> Matrix {
        let mut g = Matrix::zeros(self.cols, self.cols);
        for j in 0..self.cols {
            for k in j..self.cols {
                let mut s = 0.0;
                for i in 0..self.rows {
                    s += self.get(i, j) * self.get(i, k);
                }
                g.set(j, k, s);
                g.set(k, j, s);
            }
        }
        g
    }

    /// A A^T (rows x rows).
    pub fn gram_t(&self) -> Matrix {
        let mut g = Matrix::zeros(self.rows, self.rows);
        for i in 0..self.rows {
            for k in i..self.rows {
                let mut s = 0.0;
                let a = &self.data[i * self.cols..(i + 1) * self.cols];
                let b = &self.data[k * self.cols..(k + 1) * self.cols];
                for (x, y) in a.iter().zip(b) {
                    s += x * y;
                }
                g.set(i, k, s);
                g.set(k, i, s);
            }
        }
        g
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_is_symmetric_and_correct() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let g = a.gram();
        // A^T A = [[10, 14], [14, 20]]
        assert_eq!(g.get(0, 0), 10.0);
        assert_eq!(g.get(0, 1), 14.0);
        assert_eq!(g.get(1, 0), 14.0);
        assert_eq!(g.get(1, 1), 20.0);
    }

    #[test]
    fn gram_t_correct() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let g = a.gram_t();
        // A A^T = [[5, 11], [11, 25]]
        assert_eq!(g.get(0, 0), 5.0);
        assert_eq!(g.get(0, 1), 11.0);
        assert_eq!(g.get(1, 1), 25.0);
    }

    #[test]
    fn from_f32_preserves_layout() {
        let m = Matrix::from_f32_rows(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 0), 4.0);
    }
}
