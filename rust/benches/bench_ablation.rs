//! Ablation A1 (DESIGN.md §4): beta-warmup on/off. Shows why the paper
//! protects the first local epochs from the clustering pull — snapping
//! a never-free-trained model costs accuracy for the same bytes.

use fedcompress::compression::accounting::ccr;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;

fn main() {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_ablation: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).unwrap();

    let mut base = FedConfig::quick("cifar10");
    base.rounds = 6;
    base.clients = 4;
    base.train_size = 384;
    base.validate().unwrap();
    let data = build_data(&engine, &base).unwrap();

    let fedavg = run_federated_with_data(&engine, &base, "fedavg", &data).unwrap();

    for (label, warm_epochs, warm_rounds) in [
        ("warmup_on (paper)", base.beta_warmup_epochs, base.warmup_rounds),
        ("epoch_warmup_off", 0usize, base.warmup_rounds),
        ("round_warmup_off", base.beta_warmup_epochs, 0usize),
        ("all_warmup_off", 0, 0),
    ] {
        let mut cfg = base.clone();
        cfg.beta_warmup_epochs = warm_epochs;
        cfg.warmup_rounds = warm_rounds;
        let r = run_federated_with_data(&engine, &cfg, "fedcompress", &data).unwrap();
        println!(
            "ROW ablation variant=\"{label}\" final_acc={:.4} dAcc={:+.2}pp CCR={:.2} MCR={:.2}",
            r.final_accuracy,
            (r.final_accuracy - fedavg.final_accuracy) * 100.0,
            ccr(&fedavg.ledger, &r.ledger),
            r.mcr()
        );
    }
}
