//! Run-store throughput — thin wrapper over the shared suite function
//! in `fedcompress::bench::suite`: record encode/decode, content-key
//! hashing, append, and the checksum-verifying open scan. No artifacts
//! needed — records come from the sweep's synthetic runner. Same rows
//! as `bench run --area store`.

use fedcompress::bench::suite::{store, SuiteCtx};

fn main() {
    let mut ctx = SuiteCtx::new(false);
    store(&mut ctx).unwrap();
}
