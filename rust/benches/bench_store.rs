//! Run-store throughput: record encode/decode, content-key hashing,
//! append, and the checksum-verifying open scan. No artifacts needed —
//! records come from the sweep's synthetic runner.

use fedcompress::bench::{bench, report_throughput};
use fedcompress::config::FedConfig;
use fedcompress::store::{run_key, RunRecord, RunStore};
use fedcompress::sweep::{JobRunner, SmokeRunner, SweepJob};

fn smoke_record(seed: u64) -> RunRecord {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.seed = seed;
    cfg.rounds = 20;
    cfg.clients = 20;
    let job = SweepJob {
        idx: 0,
        strategy: "fedcompress".to_string(),
        cfg: cfg.clone(),
        key: run_key("fedcompress", &cfg),
    };
    SmokeRunner.run(&job).unwrap()
}

fn main() {
    let rec = smoke_record(1);
    let body = rec.to_body_bytes();
    println!(
        "record: {} rounds, {} transfers, {} B body",
        rec.rounds.len(),
        rec.ledger.transfer_count(),
        body.len()
    );

    let r = bench("store_record_encode", || {
        std::hint::black_box(rec.to_body_bytes());
    });
    report_throughput(&r, body.len());

    let r = bench("store_record_decode", || {
        std::hint::black_box(RunRecord::from_body_bytes(&body).unwrap());
    });
    report_throughput(&r, body.len());

    let cfg = FedConfig::paper("cifar10");
    bench("store_run_key", || {
        std::hint::black_box(run_key("fedcompress", &cfg));
    });

    // append + open over a populated store; append is measured once
    // over a fixed batch (the adaptive harness would grow the file —
    // and the derived index.json rewrite — without bound)
    let dir = std::env::temp_dir().join("fedcompress_bench_store");
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = RunStore::open(&dir).unwrap();
    let records: Vec<RunRecord> = (0..64u64).map(smoke_record).collect();
    let t0 = std::time::Instant::now();
    for rec in &records {
        store.append(rec).unwrap();
    }
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "BENCH store_append_batch n={} total_ms={:.1} per_append_us={:.1}",
        records.len(),
        total_ms,
        1e3 * total_ms / records.len() as f64
    );
    let per_entry = body.len() + 16;

    let entries = store.metas().len();
    let file_len = std::fs::metadata(dir.join("runs.fcr")).unwrap().len() as usize;
    println!("store: {entries} entries, {file_len} B file");
    let r = bench("store_open_scan", || {
        std::hint::black_box(RunStore::open(&dir).unwrap());
    });
    report_throughput(&r, file_len);

    let key = records[0].key;
    let r = bench("store_get", || {
        std::hint::black_box(store.get(key).unwrap().unwrap());
    });
    report_throughput(&r, per_entry);

    let _ = std::fs::remove_dir_all(&dir);
}
