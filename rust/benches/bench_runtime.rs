//! PJRT runtime benchmarks: per-entry execution latency — the dominant
//! cost of a federated round. One number per (dataset, entry).

use fedcompress::bench::bench;
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::literals::Arg;
use fedcompress::runtime::Engine;
use fedcompress::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_runtime: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).unwrap();
    let mut rng = Rng::new(4);

    for dataset in ["cifar10", "speechcommands"] {
        let ds = engine.manifest.dataset(dataset).unwrap().clone();
        let p = ds.spec.param_count;
        let (c, h, w) = ds.spec.input_shape;
        let b = engine.manifest.batch;
        let eb = engine.manifest.eval_batch;
        let c_max = engine.manifest.c_max;

        let theta = engine.init_theta(dataset).unwrap();
        let mu: Vec<f32> = (0..c_max).map(|i| -0.5 + i as f32 / c_max as f32).collect();
        let mask: Vec<f32> = (0..c_max).map(|i| (i < 16) as u8 as f32).collect();
        let x: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(ds.spec.num_classes) as i32).collect();
        let xe: Vec<f32> = (0..eb * c * h * w).map(|_| rng.normal()).collect();
        let ye: Vec<i32> = (0..eb).map(|_| rng.below(ds.spec.num_classes) as i32).collect();
        let teacher = theta.clone();

        engine.warmup(dataset).unwrap();

        bench(&format!("{dataset}_train_step_p{p}"), || {
            let out = engine
                .run(
                    dataset,
                    "train_step",
                    &[
                        Arg::F32(&theta),
                        Arg::F32(&mu),
                        Arg::F32(&mask),
                        Arg::F32(&x),
                        Arg::I32(&y),
                        Arg::Scalar(0.05),
                        Arg::Scalar(0.5),
                    ],
                )
                .unwrap();
            black_box(out.len());
        });

        bench(&format!("{dataset}_distill_step_p{p}"), || {
            let out = engine
                .run(
                    dataset,
                    "distill_step",
                    &[
                        Arg::F32(&theta),
                        Arg::F32(&teacher),
                        Arg::F32(&mu),
                        Arg::F32(&mask),
                        Arg::F32(&x),
                        Arg::Scalar(0.05),
                        Arg::Scalar(0.5),
                        Arg::Scalar(2.0),
                    ],
                )
                .unwrap();
            black_box(out.len());
        });

        bench(&format!("{dataset}_eval_step"), || {
            let out = engine
                .run(
                    dataset,
                    "eval_step",
                    &[Arg::F32(&theta), Arg::F32(&xe), Arg::I32(&ye)],
                )
                .unwrap();
            black_box(out.len());
        });

        bench(&format!("{dataset}_embed"), || {
            let out = engine
                .run(dataset, "embed", &[Arg::F32(&theta), Arg::F32(&xe)])
                .unwrap();
            black_box(out.len());
        });

        bench(&format!("{dataset}_snap_hlo"), || {
            let out = engine
                .run(
                    dataset,
                    "snap",
                    &[Arg::F32(&theta), Arg::F32(&mu), Arg::F32(&mask)],
                )
                .unwrap();
            black_box(out.len());
        });
    }
}
