//! PJRT runtime benchmarks — thin wrapper over the shared suite
//! function in `fedcompress::bench::suite`: per-entry execution
//! latency, the dominant cost of a federated round. Skips cleanly when
//! AOT artifacts are absent. Same rows as `bench run --area runtime`.

use fedcompress::bench::suite::{runtime, SuiteCtx};

fn main() {
    let mut ctx = SuiteCtx::new(false);
    runtime(&mut ctx).unwrap();
}
