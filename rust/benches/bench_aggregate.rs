//! Aggregation + score benchmarks: FedAvg over M client vectors and the
//! representation-score SVD — the two pure-rust stages of every round.

use fedcompress::bench::{bench, report_throughput};
use fedcompress::clustering::representation_score;
use fedcompress::coordinator::aggregate::fedavg;
use fedcompress::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let mut rng = Rng::new(3);
    for &(p, m) in &[(19_674usize, 20usize), (100_000, 20), (19_674, 100)] {
        let clients: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..p).map(|_| rng.normal()).collect())
            .collect();
        let weights: Vec<usize> = (0..m).map(|i| 50 + i).collect();
        let r = bench(&format!("fedavg_p{p}_m{m}"), || {
            let agg = fedavg(black_box(&clients), black_box(&weights)).unwrap();
            black_box(agg[0]);
        });
        report_throughput(&r, p * m * 4);
    }

    for &(n, d) in &[(64usize, 32usize), (256, 32), (64, 64)] {
        let emb: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        bench(&format!("repr_score_n{n}_d{d}"), || {
            let s = representation_score(black_box(&emb), n, d);
            black_box(s);
        });
    }
}
