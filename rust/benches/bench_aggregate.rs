//! Aggregation + score benchmarks — thin wrapper over the shared suite
//! function in `fedcompress::bench::suite`: FedAvg over M client
//! vectors and the representation-score SVD, the two pure-rust stages
//! of every round. Same rows as `bench run --area aggregate`.

use fedcompress::bench::suite::{aggregate, SuiteCtx};

fn main() {
    let mut ctx = SuiteCtx::new(false);
    aggregate(&mut ctx).unwrap();
}
