//! Table 2 regeneration bench: edge latency model over both
//! architectures, all devices, all precisions, plus the model's own
//! evaluation cost (it is pure arithmetic — microseconds).

use fedcompress::bench::bench;
use fedcompress::edge::{inference_latency, speedup, Precision, WeightFormat, EDGE_DEVICES};
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;
use std::hint::black_box;

fn main() {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_table2: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).unwrap();

    let _ = engine; // manifest presence gates the bench; specs are paper-scale
    for spec in [
        fedcompress::edge::paper_models::resnet20(),
        fedcompress::edge::paper_models::mobilenet(),
    ] {
        let model = spec.name.clone();
        let dataset = if spec.domain == "vision" { "cifar10" } else { "speechcommands" };
        for d in &EDGE_DEVICES {
            for (prec, pname) in [(Precision::F32, "f32"), (Precision::U8, "u8")] {
                let s = speedup(&spec, d, prec, 16);
                let dense = inference_latency(&spec, d, prec, WeightFormat::Dense);
                println!(
                    "ROW table2 model={model} device=\"{}\" prec={pname} speedup={s:.3} dense_us={dense:.1}",
                    d.name
                );
            }
        }
        bench(&format!("edge_model_eval_{dataset}"), || {
            let s = speedup(black_box(&spec), &EDGE_DEVICES[0], Precision::F32, 16);
            black_box(s);
        });
    }
}
