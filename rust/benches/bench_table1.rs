//! Table 1 regeneration bench (reduced budget): one paired federated
//! run of all four strategies on the cifar10 analogue, printing the
//! paper-style row plus per-round wall time. This is the end-to-end
//! system benchmark — it exercises every layer.

use fedcompress::compression::accounting::ccr;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;

fn main() {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_table1: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).unwrap();

    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = 6;
    cfg.clients = 4;
    cfg.train_size = 384;
    cfg.validate().unwrap();

    let data = build_data(&engine, &cfg).unwrap();
    let t_all = std::time::Instant::now();
    let mut results = Vec::new();
    for strategy in fedcompress::exp::table1::COLUMNS {
        let t0 = std::time::Instant::now();
        let r = run_federated_with_data(&engine, &cfg, strategy, &data).unwrap();
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "BENCH table1_{} total_ms={:.0} per_round_ms={:.0} final_acc={:.4}",
            strategy,
            total_ms,
            total_ms / cfg.rounds as f64,
            r.final_accuracy
        );
        results.push(r);
    }
    let fedavg = &results[0];
    print!("ROW cifar10 fedavg_acc={:.2}", fedavg.final_accuracy * 100.0);
    for r in &results[1..] {
        print!(
            " | {} dAcc={:+.2} CCR={:.2} MCR={:.2}",
            r.strategy,
            (r.final_accuracy - fedavg.final_accuracy) * 100.0,
            ccr(&fedavg.ledger, &r.ledger),
            r.mcr()
        );
    }
    println!();
    println!(
        "BENCH table1_total wall_s={:.1}",
        t_all.elapsed().as_secs_f64()
    );
}
