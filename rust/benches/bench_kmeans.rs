//! k-means benchmarks — thin wrapper over the shared suite function in
//! `fedcompress::bench::suite` (the server re-fits codebooks: FedZip
//! per upload, FedCompress at warmup exit / final snap, so Lloyd
//! iterations sit on the coordinator path). Same rows as the `kmeans`
//! suite of `bench run --area codec`.

use fedcompress::bench::suite::{kmeans, SuiteCtx};

fn main() {
    let mut ctx = SuiteCtx::new(false);
    kmeans(&mut ctx).unwrap();
}
