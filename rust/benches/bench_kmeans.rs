//! k-means benchmarks: the server re-fits codebooks (FedZip per upload;
//! FedCompress at warmup exit / final snap), so Lloyd iterations sit on
//! the coordinator path.

use fedcompress::bench::bench;
use fedcompress::compression::kmeans::{assign_sorted, kmeans_1d, kmeans_pp_init};
use fedcompress::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let mut rng = Rng::new(2);
    for &p in &[19_674usize, 100_000] {
        let weights: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();

        for &c in &[15usize, 16, 32] {
            bench(&format!("kmeanspp_init_p{p}_c{c}"), || {
                let mut r = Rng::new(3);
                let cb = kmeans_pp_init(black_box(&weights), c, &mut r);
                black_box(cb.len());
            });
            bench(&format!("kmeans_full_p{p}_c{c}"), || {
                let mut r = Rng::new(3);
                let (cb, _, _) = kmeans_1d(black_box(&weights), c, 25, &mut r);
                black_box(cb.len());
            });
        }

        let mut r = Rng::new(3);
        let (cb, _, _) = kmeans_1d(&weights, 16, 25, &mut r);
        bench(&format!("assign_all_p{p}_c16"), || {
            let mut acc = 0usize;
            for &w in black_box(&weights) {
                acc += assign_sorted(w, black_box(&cb));
            }
            black_box(acc);
        });
    }
}
