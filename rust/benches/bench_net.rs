//! Transport hot-path benchmarks: frame encode/decode, full protocol
//! message round-trips, loopback TCP frame throughput — the
//! per-client per-round cost a networked coordinator pays on top of
//! the codec work `bench_codec` measures — and a fleet-scale mux
//! smoke: N simulated clients streamed over a handful of sockets
//! through `Mux` + `StreamAccumulator`, reporting throughput, the
//! accumulator's reorder window, and peak RSS. Prints a MiB/s table
//! plus one machine-readable `FLEET ...` line.
//!
//! Env knobs (CI's memory gate drives these):
//!   FEDCOMPRESS_BENCH_CLIENTS     fleet size for the mux smoke
//!                                 (default 10000)
//!   FEDCOMPRESS_BENCH_FLEET_ONLY  set to skip the micro benches and
//!                                 emit only the FLEET line

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use fedcompress::bench::bench;
use fedcompress::codec::StageBytes;
use fedcompress::coordinator::accumulate::{FedAvgFold, StreamAccumulator};
use fedcompress::coordinator::strategy::ClientUpdate;
use fedcompress::net::frame::{encode_frame, framed_len, read_frame, write_frame};
use fedcompress::net::mux::{Mux, MuxEvent};
use fedcompress::net::proto::{Msg, Upload};
use fedcompress::util::rng::Rng;
use std::hint::black_box;

fn mib_s(bytes_per_iter: usize, median_ns: f64) -> f64 {
    (bytes_per_iter as f64 / (1 << 20) as f64) / (median_ns * 1e-9)
}

/// Peak resident set of this process so far, in kB (`VmHWM` from
/// /proc/self/status). None off Linux — the caller prints 0.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("VmHWM:"))
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|n| n.parse().ok())
}

/// The fleet-scale smoke: `clients` logical uploads stream over
/// `workers` sockets into one readiness loop that folds each one on
/// arrival. Coordinator-side memory is the accumulator's reorder
/// window plus one fold state — NOT `clients` buffered uploads — and
/// the `FLEET` line carries the peak RSS that CI holds flat across
/// fleet sizes.
fn fleet_smoke(clients: usize, workers: usize, params: usize) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // each peer owns clients k % workers == w and streams one raw
    // upload frame per client: client id (u32) + params f32 LE
    let peers: Vec<_> = (0..workers)
        .map(|w| {
            thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_nodelay(true).ok();
                let mut rng = Rng::new(w as u64 + 1);
                let mut body = Vec::with_capacity(4 + params * 4);
                for k in (w..clients).step_by(workers) {
                    body.clear();
                    body.extend_from_slice(&(k as u32).to_le_bytes());
                    for _ in 0..params {
                        body.extend_from_slice(&rng.normal().to_le_bytes());
                    }
                    write_frame(&mut &stream, 9, &body).unwrap();
                }
                // hold the socket open until the coordinator is done —
                // closing early would race the last buffered frames
                let mut sink = Vec::new();
                let _ = stream.read_to_end(&mut sink);
            })
        })
        .collect();

    let streams: Vec<TcpStream> = (0..workers)
        .map(|_| listener.accept().unwrap().0)
        .collect();
    let mut mux = Mux::new(streams).unwrap();
    let mut acc = StreamAccumulator::new(Box::new(FedAvgFold::new()), clients);

    let start = Instant::now();
    let mut events = Vec::new();
    let mut resolved = 0usize;
    while resolved < clients {
        events.clear();
        let progress = mux.poll(&mut events);
        for ev in &events {
            match ev {
                MuxEvent::Frame { payload, .. } => {
                    let k = u32::from_le_bytes(payload[..4].try_into().unwrap()) as usize;
                    let theta: Vec<f32> = payload[4..]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    let up = ClientUpdate {
                        client: k,
                        theta,
                        mu: vec![0.0; 4],
                        score: 1.0,
                        n: 1,
                    };
                    acc.resolve_upload(k, up).unwrap();
                    resolved += 1;
                }
                MuxEvent::Closed { conn, error } => {
                    panic!("fleet smoke: conn {conn} died early: {error}")
                }
            }
        }
        if !progress {
            thread::sleep(Duration::from_micros(100));
        }
    }
    let peak_parked = acc.peak_parked();
    let out = acc.finish().unwrap();
    assert_eq!(out.clients, clients, "every upload folded");
    assert_eq!(out.theta.len(), params);
    let elapsed = start.elapsed();

    for c in 0..workers {
        mux.close(c); // releases the peers' read_to_end
    }
    for p in peers {
        p.join().unwrap();
    }

    let secs = elapsed.as_secs_f64();
    println!(
        "FLEET clients={} workers={} params={} elapsed_ms={:.1} uploads_per_s={:.0} \
         peak_parked={} peak_rss_kb={}",
        clients,
        workers,
        params,
        secs * 1e3,
        clients as f64 / secs,
        peak_parked,
        peak_rss_kb().unwrap_or(0),
    );
}

fn main() {
    let fleet_clients: usize = std::env::var("FEDCOMPRESS_BENCH_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    if std::env::var("FEDCOMPRESS_BENCH_FLEET_ONLY").is_ok() {
        fleet_smoke(fleet_clients, 8, 256);
        return;
    }

    let mut rng = Rng::new(1);
    println!(
        "{:<34} {:>12} {:>10}",
        "case", "median_ns", "MiB/s"
    );

    // --- frame codec ------------------------------------------------------
    for &size in &[1_000usize, 78_696, 1_000_000] {
        let payload: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        let r = bench(&format!("frame_encode_{size}B"), || {
            let f = encode_frame(4, black_box(&payload));
            black_box(f.len());
        });
        println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(size, r.median_ns));

        let frame = encode_frame(4, &payload);
        let r = bench(&format!("frame_decode_{size}B"), || {
            let (ty, body) = read_frame(&mut black_box(&frame[..])).unwrap();
            black_box((ty, body.len()));
        });
        println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(size, r.median_ns));
    }

    // --- full Upload message (the per-client per-round unit) --------------
    let payload: Vec<u8> = (0..20_000).map(|_| rng.below(256) as u8).collect();
    let upload = Msg::Upload(Upload {
        round: 3,
        client: 7,
        score: 4.5,
        n: 96,
        mean_ce: 1.25,
        mu: (0..32).map(|_| rng.normal()).collect(),
        stages: vec![
            StageBytes {
                stage: "codebook".to_string(),
                bytes: 24_000,
            },
            StageBytes {
                stage: "huffman".to_string(),
                bytes: 20_000,
            },
        ],
        spec: "codebook|huffman".to_string(),
        payload: payload.clone(),
    });
    let encoded = {
        let mut buf = Vec::new();
        upload.write_to(&mut buf).unwrap();
        buf
    };
    let r = bench("upload_msg_encode_20kB", || {
        let mut buf = Vec::with_capacity(encoded.len());
        upload.write_to(&mut buf).unwrap();
        black_box(buf.len());
    });
    println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(encoded.len(), r.median_ns));
    let r = bench("upload_msg_decode_20kB", || {
        let m = Msg::read_from(&mut black_box(&encoded[..])).unwrap();
        black_box(m.kind());
    });
    println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(encoded.len(), r.median_ns));

    // --- loopback TCP round-trip ------------------------------------------
    // an echo peer: every received frame comes straight back
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let echo = thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        stream.set_nodelay(true).ok();
        while let Ok((ty, payload)) = read_frame(&mut &stream) {
            if write_frame(&mut &stream, ty, &payload).is_err() {
                break;
            }
        }
    });
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    for &size in &[1_000usize, 78_696, 1_000_000] {
        let payload: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        let r = bench(&format!("loopback_roundtrip_{size}B"), || {
            write_frame(&mut &stream, 4, black_box(&payload)).unwrap();
            let (_, body) = read_frame(&mut &stream).unwrap();
            black_box(body.len());
        });
        // a round trip moves the frame both ways
        let moved = 2 * framed_len(size);
        println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(moved, r.median_ns));
    }
    drop(stream);
    echo.join().unwrap();

    // --- fleet-scale mux smoke --------------------------------------------
    fleet_smoke(fleet_clients, 8, 256);
}
