//! Transport hot-path benchmarks. The micro suites (frame codec,
//! protocol messages, loopback TCP) live in `fedcompress::bench::suite`
//! and are shared with the headless `bench run --area net` verb — this
//! target wraps them, then runs the fleet-scale mux smoke: N simulated
//! clients streamed over a handful of sockets through `Mux` +
//! `StreamAccumulator`, reporting throughput, the accumulator's
//! reorder window, and peak RSS via one machine-readable `FLEET ...`
//! line (CI's flat-memory gate greps it).
//!
//! Env knobs (CI's memory gate drives these):
//!   FEDCOMPRESS_BENCH_CLIENTS     fleet size for the mux smoke
//!                                 (default 10000)
//!   FEDCOMPRESS_BENCH_FLEET_ONLY  set to skip the micro benches and
//!                                 emit only the FLEET line

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use fedcompress::bench::suite::{net_micro, SuiteCtx};
use fedcompress::coordinator::accumulate::{FedAvgFold, StreamAccumulator};
use fedcompress::coordinator::strategy::ClientUpdate;
use fedcompress::net::frame::write_frame;
use fedcompress::net::mux::{Mux, MuxEvent};
use fedcompress::util::rng::Rng;
use fedcompress::util::timer::Stopwatch;

/// Peak resident set of this process so far, in kB (`VmHWM` from
/// /proc/self/status). None off Linux — the caller prints 0.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("VmHWM:"))
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|n| n.parse().ok())
}

/// The fleet-scale smoke: `clients` logical uploads stream over
/// `workers` sockets into one readiness loop that folds each one on
/// arrival. Coordinator-side memory is the accumulator's reorder
/// window plus one fold state — NOT `clients` buffered uploads — and
/// the `FLEET` line carries the peak RSS that CI holds flat across
/// fleet sizes.
fn fleet_smoke(clients: usize, workers: usize, params: usize) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // each peer owns clients k % workers == w and streams one raw
    // upload frame per client: client id (u32) + params f32 LE
    let peers: Vec<_> = (0..workers)
        .map(|w| {
            thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_nodelay(true).ok();
                let mut rng = Rng::new(w as u64 + 1);
                let mut body = Vec::with_capacity(4 + params * 4);
                for k in (w..clients).step_by(workers) {
                    body.clear();
                    body.extend_from_slice(&(k as u32).to_le_bytes());
                    for _ in 0..params {
                        body.extend_from_slice(&rng.normal().to_le_bytes());
                    }
                    write_frame(&mut &stream, 9, &body).unwrap();
                }
                // hold the socket open until the coordinator is done —
                // closing early would race the last buffered frames
                let mut sink = Vec::new();
                let _ = stream.read_to_end(&mut sink);
            })
        })
        .collect();

    let streams: Vec<TcpStream> = (0..workers)
        .map(|_| listener.accept().unwrap().0)
        .collect();
    let mut mux = Mux::new(streams).unwrap();
    let mut acc = StreamAccumulator::new(Box::new(FedAvgFold::new()), clients);

    let sw = Stopwatch::start();
    let mut events = Vec::new();
    let mut resolved = 0usize;
    while resolved < clients {
        events.clear();
        let progress = mux.poll(&mut events);
        for ev in &events {
            match ev {
                MuxEvent::Frame { payload, .. } => {
                    let k = u32::from_le_bytes(payload[..4].try_into().unwrap()) as usize;
                    let theta: Vec<f32> = payload[4..]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    let up = ClientUpdate {
                        client: k,
                        theta,
                        mu: vec![0.0; 4],
                        score: 1.0,
                        n: 1,
                    };
                    acc.resolve_upload(k, up).unwrap();
                    resolved += 1;
                }
                MuxEvent::Closed { conn, error } => {
                    panic!("fleet smoke: conn {conn} died early: {error}")
                }
            }
        }
        if !progress {
            thread::sleep(Duration::from_micros(100));
        }
    }
    let peak_parked = acc.peak_parked();
    let out = acc.finish().unwrap();
    assert_eq!(out.clients, clients, "every upload folded");
    assert_eq!(out.theta.len(), params);
    let secs = sw.elapsed_s();

    for c in 0..workers {
        mux.close(c); // releases the peers' read_to_end
    }
    for p in peers {
        p.join().unwrap();
    }

    println!(
        "FLEET clients={} workers={} params={} elapsed_ms={:.1} uploads_per_s={:.0} \
         peak_parked={} peak_rss_kb={}",
        clients,
        workers,
        params,
        secs * 1e3,
        clients as f64 / secs,
        peak_parked,
        peak_rss_kb().unwrap_or(0),
    );
}

fn main() {
    let fleet_clients: usize = std::env::var("FEDCOMPRESS_BENCH_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    if std::env::var("FEDCOMPRESS_BENCH_FLEET_ONLY").is_ok() {
        fleet_smoke(fleet_clients, 8, 256);
        return;
    }

    let mut ctx = SuiteCtx::new(false);
    net_micro(&mut ctx).unwrap();

    // --- fleet-scale mux smoke --------------------------------------------
    fleet_smoke(fleet_clients, 8, 256);
}
