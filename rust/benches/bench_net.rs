//! Transport hot-path benchmarks: frame encode/decode, full protocol
//! message round-trips, and loopback TCP frame throughput — the
//! per-client per-round cost a networked coordinator pays on top of
//! the codec work `bench_codec` measures. Prints a MiB/s table.

use std::net::{TcpListener, TcpStream};
use std::thread;

use fedcompress::bench::bench;
use fedcompress::codec::StageBytes;
use fedcompress::net::frame::{encode_frame, framed_len, read_frame, write_frame};
use fedcompress::net::proto::{Msg, Upload};
use fedcompress::util::rng::Rng;
use std::hint::black_box;

fn mib_s(bytes_per_iter: usize, median_ns: f64) -> f64 {
    (bytes_per_iter as f64 / (1 << 20) as f64) / (median_ns * 1e-9)
}

fn main() {
    let mut rng = Rng::new(1);
    println!(
        "{:<34} {:>12} {:>10}",
        "case", "median_ns", "MiB/s"
    );

    // --- frame codec ------------------------------------------------------
    for &size in &[1_000usize, 78_696, 1_000_000] {
        let payload: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        let r = bench(&format!("frame_encode_{size}B"), || {
            let f = encode_frame(4, black_box(&payload));
            black_box(f.len());
        });
        println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(size, r.median_ns));

        let frame = encode_frame(4, &payload);
        let r = bench(&format!("frame_decode_{size}B"), || {
            let (ty, body) = read_frame(&mut black_box(&frame[..])).unwrap();
            black_box((ty, body.len()));
        });
        println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(size, r.median_ns));
    }

    // --- full Upload message (the per-client per-round unit) --------------
    let payload: Vec<u8> = (0..20_000).map(|_| rng.below(256) as u8).collect();
    let upload = Msg::Upload(Upload {
        round: 3,
        client: 7,
        score: 4.5,
        n: 96,
        mean_ce: 1.25,
        mu: (0..32).map(|_| rng.normal()).collect(),
        stages: vec![
            StageBytes {
                stage: "codebook".to_string(),
                bytes: 24_000,
            },
            StageBytes {
                stage: "huffman".to_string(),
                bytes: 20_000,
            },
        ],
        spec: "codebook|huffman".to_string(),
        payload: payload.clone(),
    });
    let encoded = {
        let mut buf = Vec::new();
        upload.write_to(&mut buf).unwrap();
        buf
    };
    let r = bench("upload_msg_encode_20kB", || {
        let mut buf = Vec::with_capacity(encoded.len());
        upload.write_to(&mut buf).unwrap();
        black_box(buf.len());
    });
    println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(encoded.len(), r.median_ns));
    let r = bench("upload_msg_decode_20kB", || {
        let m = Msg::read_from(&mut black_box(&encoded[..])).unwrap();
        black_box(m.kind());
    });
    println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(encoded.len(), r.median_ns));

    // --- loopback TCP round-trip ------------------------------------------
    // an echo peer: every received frame comes straight back
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let echo = thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        stream.set_nodelay(true).ok();
        while let Ok((ty, payload)) = read_frame(&mut &stream) {
            if write_frame(&mut &stream, ty, &payload).is_err() {
                break;
            }
        }
    });
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    for &size in &[1_000usize, 78_696, 1_000_000] {
        let payload: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        let r = bench(&format!("loopback_roundtrip_{size}B"), || {
            write_frame(&mut &stream, 4, black_box(&payload)).unwrap();
            let (_, body) = read_frame(&mut &stream).unwrap();
            black_box(body.len());
        });
        // a round trip moves the frame both ways
        let moved = 2 * framed_len(size);
        println!("{:<34} {:>12.0} {:>10.1}", r.name, r.median_ns, mib_s(moved, r.median_ns));
    }
    drop(stream);
    echo.join().unwrap();
}
