//! Codec hot-path benchmarks — thin wrapper over the shared suite
//! functions in `fedcompress::bench::suite`, so `cargo bench` and the
//! headless `bench run --area codec` verb measure identical code and
//! emit identical row names (pipelines, per-stage profile, quantize /
//! huffman / flat primitives).

use fedcompress::bench::suite::{codec_pipelines, codec_primitives, SuiteCtx};

fn main() {
    let mut ctx = SuiteCtx::new(false);
    codec_pipelines(&mut ctx).unwrap();
    codec_primitives(&mut ctx).unwrap();
}
