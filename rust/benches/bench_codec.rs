//! Codec hot-path benchmarks: encode/decode of clustered model updates
//! at realistic model sizes — the L3 coordinator pays this per client
//! per round in both directions.

use fedcompress::bench::{bench, report_throughput};
use fedcompress::compression::codec::{decode, encode, quantize_and_encode};
use fedcompress::compression::huffman::{huffman_decode, huffman_encode};
use fedcompress::compression::kmeans::kmeans_1d;
use fedcompress::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let mut rng = Rng::new(1);
    for &(p, c) in &[(19_674usize, 16usize), (19_674, 32), (100_000, 16)] {
        let weights: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();
        let (cb, _, _) = kmeans_1d(&weights, c, 25, &mut rng);

        let r = bench(&format!("quantize_encode_p{p}_c{c}"), || {
            let (enc, _) = quantize_and_encode(black_box(&weights), black_box(&cb));
            black_box(enc.wire_bytes());
        });
        report_throughput(&r, p * 4);

        let (enc, _) = quantize_and_encode(&weights, &cb);
        let r = bench(&format!("decode_p{p}_c{c}"), || {
            let out = decode(black_box(&enc.bytes)).unwrap();
            black_box(out.0.len());
        });
        report_throughput(&r, enc.bytes.len());

        // pure huffman on the index stream
        let idx: Vec<u32> = (0..p).map(|_| rng.below(c) as u32).collect();
        bench(&format!("huffman_encode_p{p}_c{c}"), || {
            let e = huffman_encode(black_box(&idx), c);
            black_box(e.payload_bits);
        });
        let henc = huffman_encode(&idx, c);
        bench(&format!("huffman_decode_p{p}_c{c}"), || {
            let d = huffman_decode(black_box(&henc)).unwrap();
            black_box(d.len());
        });

        // flat-pack path (encode() picks it for uniform indices)
        bench(&format!("flat_encode_p{p}_c{c}"), || {
            let e = encode(black_box(&cb), black_box(&idx));
            black_box(e.bytes.len());
        });
    }
}
