//! Codec hot-path benchmarks: encode/decode of clustered model updates
//! at realistic model sizes — the L3 coordinator pays this per client
//! per round in both directions — plus the registry-built pipelines
//! (per-stage primitives and full `topk|kmeans|huffman`-style stacks)
//! the strategies now declare.

use fedcompress::bench::{bench, report_throughput};
use fedcompress::clustering::CentroidState;
use fedcompress::codec::{Codec, CodecInput, CodecRegistry};
use fedcompress::compression::codec::{decode, encode, quantize_and_encode};
use fedcompress::compression::huffman::{huffman_decode, huffman_encode};
use fedcompress::compression::kmeans::kmeans_1d;
use fedcompress::util::rng::Rng;
use std::hint::black_box;

/// Registry pipelines: encode + decode MiB/s per spec, at one
/// realistic model size. Dense-input MiB are the throughput unit for
/// encode; payload MiB for decode.
fn bench_pipelines(rng: &mut Rng) {
    let p = 19_674usize;
    let theta: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();
    let cents = CentroidState::init_from_weights(&theta, 16, 32, rng);
    let reg = CodecRegistry::builtin();

    for spec in [
        "dense",
        "topk(keep=0.1)",
        "kmeans(c=16,iters=25)",
        "codebook",
        "topk(keep=0.6)|kmeans(c=15,iters=25)|huffman",
        "codebook|huffman",
        "codebook|delta",
    ] {
        let pipe = reg.build(spec).unwrap();
        let input = CodecInput {
            theta: &theta,
            centroids: Some(&cents),
            stream: fedcompress::codec::stream::FINAL,
        };
        let r = bench(&format!("pipe_encode[{spec}]"), || {
            let mut enc_rng = Rng::new(7);
            let blob = pipe.encode(black_box(&input), &mut enc_rng).unwrap();
            black_box(blob.payload.len());
        });
        report_throughput(&r, 4 * p);

        // the decode-bench blob comes from a FRESH sender instance:
        // the loop above advanced `pipe`'s delta stream state, and a
        // residual blob would be undecodable by a cold peer. A fresh
        // sender ships the flat baseline form, which a fresh peer
        // decodes repeatedly without needing stream history.
        let blob = reg.build(spec).unwrap().encode(&input, &mut Rng::new(7)).unwrap();
        let peer = reg.build(spec).unwrap();
        peer.decode(&blob.payload).unwrap();
        let r = bench(&format!("pipe_decode[{spec}]"), || {
            let out = peer.decode(black_box(&blob.payload)).unwrap();
            black_box(out.len());
        });
        report_throughput(&r, blob.payload.len());
    }
}

fn main() {
    let mut rng = Rng::new(1);
    bench_pipelines(&mut rng);
    for &(p, c) in &[(19_674usize, 16usize), (19_674, 32), (100_000, 16)] {
        let weights: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();
        let (cb, _, _) = kmeans_1d(&weights, c, 25, &mut rng);

        let r = bench(&format!("quantize_encode_p{p}_c{c}"), || {
            let (enc, _) = quantize_and_encode(black_box(&weights), black_box(&cb));
            black_box(enc.wire_bytes());
        });
        report_throughput(&r, p * 4);

        let (enc, _) = quantize_and_encode(&weights, &cb);
        let r = bench(&format!("decode_p{p}_c{c}"), || {
            let out = decode(black_box(&enc.bytes)).unwrap();
            black_box(out.0.len());
        });
        report_throughput(&r, enc.bytes.len());

        // pure huffman on the index stream
        let idx: Vec<u32> = (0..p).map(|_| rng.below(c) as u32).collect();
        bench(&format!("huffman_encode_p{p}_c{c}"), || {
            let e = huffman_encode(black_box(&idx), c);
            black_box(e.payload_bits);
        });
        let henc = huffman_encode(&idx, c);
        bench(&format!("huffman_decode_p{p}_c{c}"), || {
            let d = huffman_decode(black_box(&henc)).unwrap();
            black_box(d.len());
        });

        // flat-pack path (encode() picks it for uniform indices)
        bench(&format!("flat_encode_p{p}_c{c}"), || {
            let e = encode(black_box(&cb), black_box(&idx));
            black_box(e.bytes.len());
        });
    }
}
