//! SIMD kernel throughput — thin wrapper over the shared suite
//! function in `fedcompress::bench::suite`: every kernel of the
//! `fedcompress::kernels` dispatch layer, scalar vs detected backend,
//! across payload sizes from 1 KiB to 100 MiB. Same rows as
//! `bench run --area kernels`.

use fedcompress::bench::suite::{kernels, SuiteCtx};

fn main() {
    let mut ctx = SuiteCtx::new(false);
    kernels(&mut ctx).unwrap();
}
