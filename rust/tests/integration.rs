//! Cross-module integration tests that do not need the PJRT runtime:
//! codec pipelines over realistic weight vectors, the controller+
//! centroid interplay, partition->batch flows, and the mini property
//! framework driving multi-module invariants.

use fedcompress::check::{ensure, forall, pair, usize_in, vec_f32};
use fedcompress::clustering::{CentroidState, ClusterController, ControllerConfig};
use fedcompress::compression::codec::{decode, dense_bytes, quantize_and_encode};
use fedcompress::compression::huffman::{huffman_decode, huffman_encode};
use fedcompress::compression::kmeans::kmeans_1d;
use fedcompress::data::partition::{partition_dirichlet, sigma_to_alpha};
use fedcompress::data::synth::{generate, SynthSpec};
use fedcompress::util::rng::Rng;

#[test]
fn codec_roundtrip_property_over_random_weights() {
    forall(
        40,
        0xC0DEC,
        &pair(vec_f32(0.5), usize_in(2, 32)),
        |(weights, c)| {
            let mut rng = Rng::new(7);
            let (cb, _, _) = kmeans_1d(weights, *c, 20, &mut rng);
            let (enc, quantized) = quantize_and_encode(weights, &cb);
            let (dec, idx, cb2) = decode(&enc.bytes).map_err(|e| e.to_string())?;
            ensure(dec == quantized, "decode != quantized")?;
            ensure(cb2 == cb, "codebook mismatch")?;
            ensure(idx.len() == weights.len(), "index count")?;
            ensure(
                enc.wire_bytes() <= dense_bytes(weights.len()) + 64 + 4 * cb.len(),
                "encoded larger than dense + headers",
            )
        },
    );
}

#[test]
fn quantization_error_shrinks_with_more_clusters() {
    let mut rng = Rng::new(11);
    let weights: Vec<f32> = (0..8000).map(|_| rng.normal() * 0.3).collect();
    let mut last_err = f64::MAX;
    for c in [4usize, 8, 16, 32] {
        let (cb, _, _) = kmeans_1d(&weights, c, 30, &mut rng);
        let (_, q) = quantize_and_encode(&weights, &cb);
        let err: f64 = weights
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(err < last_err, "c={c}");
        last_err = err;
    }
}

#[test]
fn huffman_tracks_assignment_entropy() {
    // clustered weights from a bimodal distribution compress better than
    // uniform ones at the same C
    let mut rng = Rng::new(13);
    let bimodal: Vec<f32> = (0..10_000)
        .map(|i| {
            if i % 10 == 0 {
                rng.normal()
            } else {
                0.01 * rng.normal()
            }
        })
        .collect();
    let uniformish: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
    let (cb_b, _, _) = kmeans_1d(&bimodal, 16, 25, &mut rng);
    let (cb_u, _, _) = kmeans_1d(&uniformish, 16, 25, &mut rng);
    let (enc_b, _) = quantize_and_encode(&bimodal, &cb_b);
    let (enc_u, _) = quantize_and_encode(&uniformish, &cb_u);
    assert!(
        enc_b.wire_bytes() < enc_u.wire_bytes(),
        "{} vs {}",
        enc_b.wire_bytes(),
        enc_u.wire_bytes()
    );
}

#[test]
fn huffman_roundtrip_property() {
    forall(50, 0x0FF, &pair(usize_in(2, 64), usize_in(1, 4000)), |(alpha, n)| {
        let mut rng = Rng::new((*alpha * 31 + *n) as u64);
        // skewed symbol distribution
        let weights: Vec<f64> = (0..*alpha).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let symbols: Vec<u32> = (0..*n).map(|_| rng.categorical(&weights) as u32).collect();
        let enc = huffman_encode(&symbols, *alpha);
        let dec = huffman_decode(&enc).map_err(|e| e.to_string())?;
        ensure(dec == symbols, "huffman roundtrip")
    });
}

#[test]
fn controller_with_centroids_grows_consistently() {
    let mut rng = Rng::new(17);
    let weights: Vec<f32> = (0..4000).map(|_| rng.normal() * 0.2).collect();
    let cfg = ControllerConfig {
        c_min: 8,
        c_max: 32,
        window: 3,
        patience: 3,
        step: 8,
    };
    let mut cents = CentroidState::init_from_weights(&weights, cfg.c_min, 32, &mut rng);
    let mut ctl = ClusterController::new(cfg);
    // plateaued scores force growth; centroid state must track
    for _ in 0..30 {
        let c = ctl.observe(2.0);
        if c > cents.active {
            cents.grow_to(c);
        }
        assert_eq!(cents.active, ctl.current_c());
        assert_eq!(
            cents.mask.iter().filter(|&&m| m == 1.0).count(),
            cents.active
        );
    }
    assert_eq!(cents.active, 32);
    // codebook still sorted & within data range after repeated growth
    let cb = cents.active_codebook();
    for w in cb.windows(2) {
        assert!(w[0] <= w[1]);
    }
    assert!(cb.iter().all(|c| c.abs() < 10.0));
}

#[test]
fn partition_to_batches_flow() {
    let spec = SynthSpec::for_dataset("pathmnist");
    let data = generate(&spec, 600, 5, 0);
    let mut rng = Rng::new(23);
    let shards = partition_dirichlet(&data, 6, sigma_to_alpha(0.25), 40, &mut rng);
    assert_eq!(shards.len(), 6);
    for shard in &shards {
        let (du, dl) = shard.take(16);
        assert_eq!(du.len(), 16);
        // every client can form full train batches
        let batches = dl.epoch_batches(32, &mut rng);
        assert!(!batches.is_empty());
        for (xs, ys) in &batches {
            assert_eq!(ys.len(), 32);
            assert_eq!(xs.len(), 32 * dl.feature_len());
            assert!(ys.iter().all(|&y| (y as usize) < 9));
        }
    }
}

#[test]
fn sigma_controls_observable_heterogeneity() {
    let spec = SynthSpec::for_dataset("cifar10");
    let data = generate(&spec, 2000, 9, 0);

    let dominance = |sigma: f64, seed: u64| -> f64 {
        let mut rng = Rng::new(seed);
        let shards = partition_dirichlet(&data, 10, sigma_to_alpha(sigma), 20, &mut rng);
        shards
            .iter()
            .map(|s| {
                *s.label_histogram().iter().max().unwrap() as f64 / s.len() as f64
            })
            .sum::<f64>()
            / shards.len() as f64
    };
    // average over seeds to de-noise
    let lo: f64 = (0..5).map(|s| dominance(0.05, s)).sum::<f64>() / 5.0;
    let hi: f64 = (0..5).map(|s| dominance(0.8, s)).sum::<f64>() / 5.0;
    assert!(hi > lo + 0.1, "sigma=0.8 dominance {hi} vs sigma=0.05 {lo}");
}

#[test]
fn fedavg_of_quantized_models_stays_in_codebook_hull() {
    use fedcompress::coordinator::aggregate::fedavg;
    let mut rng = Rng::new(29);
    let weights: Vec<f32> = (0..2000).map(|_| rng.normal() * 0.25).collect();
    let (cb, _, _) = kmeans_1d(&weights, 16, 25, &mut rng);
    let clients: Vec<Vec<f32>> = (0..5)
        .map(|_| {
            let noisy: Vec<f32> =
                weights.iter().map(|w| w + 0.01 * rng.normal()).collect();
            let (_, q) = quantize_and_encode(&noisy, &cb);
            q
        })
        .collect();
    let agg = fedavg(&clients, &[1, 2, 3, 4, 5]).unwrap();
    let lo = cb.first().unwrap();
    let hi = cb.last().unwrap();
    for v in &agg {
        assert!(*v >= *lo - 1e-6 && *v <= *hi + 1e-6);
    }
}
