//! Property tests over the codec layer: every registered codec and
//! random pipeline compositions must round-trip at seeded random
//! sizes with the wire invariants holding (`payload.len() ==
//! wire_bytes`, encode->decode param-count preservation, lossless
//! stages bit-exact, compressing pipelines strictly below dense);
//! the self-describing wire header survives truncation at every offset
//! and single-bit flips without panics (mirroring the `net_proto` /
//! `store_roundtrip` corruption discipline); a custom codec registered
//! on both ends crosses a real TCP loopback socket end-to-end —
//! something the old `Opaque` carve-out could not do; and the `delta`
//! stage stays stream-synchronized between a sender and a receiver
//! across rounds. No external property-test crates: cases are driven
//! by the repo's own deterministic `Rng`.

use std::net::{TcpListener, TcpStream};

use fedcompress::clustering::CentroidState;
use fedcompress::codec::{
    stream, Codec, CodecCache, CodecError, CodecInfo, CodecInput, CodecRegistry, DataKind, Stage,
    StageData,
};
use fedcompress::compression::codec::dense_bytes;
use fedcompress::net::proto::{write_download, write_upload, Download, Msg, Upload};
use fedcompress::util::rng::Rng;

fn input<'a>(theta: &'a [f32], cents: &'a CentroidState) -> CodecInput<'a> {
    CodecInput {
        theta,
        centroids: Some(cents),
        stream: stream::FINAL,
    }
}

/// Random model state: theta from a scaled normal (occasionally with
/// heavy outliers, the k-means stressor) plus an initialized codebook.
fn random_state(n: usize, rng: &mut Rng) -> (Vec<f32>, CentroidState) {
    let scale = 0.05 + rng.f32() * 0.5;
    let heavy_tail = rng.f32() < 0.3;
    let theta: Vec<f32> = (0..n)
        .map(|_| {
            let w = rng.normal() * scale;
            if heavy_tail && rng.f32() < 0.01 {
                w * 50.0
            } else {
                w
            }
        })
        .collect();
    let cents = CentroidState::init_from_weights(&theta, 16, 32, rng);
    (theta, cents)
}

/// Pipeline templates spanning every registered stage, parameterized
/// per case. `compressing` marks specs that must come in strictly
/// below dense at the sizes this suite draws.
fn random_spec(rng: &mut Rng) -> (String, bool) {
    let keep = [0.1, 0.25, 0.5][rng.below(3)];
    let c = 2 + rng.below(31);
    let iters = 1 + rng.below(25);
    match rng.below(10) {
        0 => ("dense".to_string(), false),
        1 => (format!("topk(keep={keep})"), true),
        2 => (format!("kmeans(c={c},iters={iters})"), true),
        3 => ("codebook".to_string(), true),
        4 => (format!("topk(keep={keep})|kmeans(c={c},iters={iters})"), true),
        5 => (
            format!("topk(keep={keep})|kmeans(c={c},iters={iters})|huffman"),
            true,
        ),
        6 => (format!("kmeans(c={c},iters={iters})|huffman"), true),
        7 => ("codebook|huffman".to_string(), true),
        8 => ("codebook|delta".to_string(), true),
        _ => (
            format!("topk(keep={keep})|kmeans(c={c},iters={iters})|delta"),
            true,
        ),
    }
}

// ---------------------------------------------------------------------------
// encode -> decode property suite
// ---------------------------------------------------------------------------

#[test]
fn every_codec_round_trips_at_random_sizes() {
    let reg = CodecRegistry::builtin();
    let mut case_rng = Rng::new(0xC0DEC);
    for case in 0..60 {
        let (spec, compressing) = random_spec(&mut case_rng);
        // sizes where the compressing bound is meaningful (headers and
        // codebooks amortized)
        let n = 512 + case_rng.below(8192);
        let (theta, cents) = random_state(n, &mut case_rng);
        let pipe = reg.build(&spec).unwrap();

        let mut enc_rng = Rng::new(5000 + case as u64);
        let blob = pipe.encode(&input(&theta, &cents), &mut enc_rng).unwrap();

        // wire accounting: the ledger never lies
        assert_eq!(blob.payload.len(), blob.wire_bytes(), "{spec}");
        assert_eq!(blob.stage_bytes.last().unwrap().bytes, blob.payload.len(), "{spec}");
        // param-count invariant through any stage stack
        assert_eq!(blob.theta.len(), n, "{spec}");
        assert!(blob.theta.iter().all(|w| w.is_finite()), "{spec}");

        // a fresh receiver reconstructs the encoder's theta bit-exactly
        let receiver = reg.build(&spec).unwrap();
        let decoded = receiver.decode(&blob.payload).unwrap();
        assert_eq!(decoded, blob.theta, "{spec} n={n}");

        // compressing pipelines beat dense strictly; dense matches it
        if compressing {
            assert!(
                blob.payload.len() < dense_bytes(n),
                "{spec} n={n}: {} >= dense {}",
                blob.payload.len(),
                dense_bytes(n)
            );
        } else {
            assert_eq!(blob.payload.len(), dense_bytes(n), "{spec}");
            // lossless stage: bit-exact against the input itself
            assert_eq!(blob.theta, theta, "{spec}");
        }
    }
}

/// Same input + same RNG position => bit-identical blobs (the
/// serial==parallel guarantee the upload fan-out rests on), for every
/// template.
#[test]
fn pipeline_encode_is_deterministic_given_the_rng_fork() {
    let reg = CodecRegistry::builtin();
    let mut rng = Rng::new(0xD17E);
    for case in 0..10 {
        let (spec, _) = random_spec(&mut rng);
        let (theta, cents) = random_state(2048, &mut rng);
        // fresh pipelines per encode so stateful stages (delta) see
        // the same history on both sides of the comparison
        let a = reg
            .build(&spec)
            .unwrap()
            .encode(&input(&theta, &cents), &mut Rng::new(42 + case))
            .unwrap();
        let b = reg
            .build(&spec)
            .unwrap()
            .encode(&input(&theta, &cents), &mut Rng::new(42 + case))
            .unwrap();
        assert_eq!(a.payload, b.payload, "{spec}");
        assert_eq!(a.theta, b.theta, "{spec}");
        assert_eq!(a.stage_bytes, b.stage_bytes, "{spec}");
    }
}

// ---------------------------------------------------------------------------
// self-describing wire header corruption discipline
// ---------------------------------------------------------------------------

/// Decode a Download frame body, then its payload through the codec
/// cache. Returns the decoded theta when everything parses.
fn decode_chain(cache: &CodecCache, body: &[u8]) -> Option<Vec<f32>> {
    match Msg::decode(4, body) {
        Ok(Msg::Download(d)) => cache.decode(&d.spec, &d.payload).ok(),
        _ => None,
    }
}

#[test]
fn wire_header_survives_truncation_at_every_offset() {
    let mut rng = Rng::new(0x7C); // truncation
    let (theta, cents) = random_state(600, &mut rng);
    let reg = CodecRegistry::builtin();
    let pipe = reg.build("codebook|huffman").unwrap();
    let blob = pipe.encode(&input(&theta, &cents), &mut rng).unwrap();

    let msg = Msg::Download(Download {
        round: 3,
        client: 1,
        spec: pipe.spec(),
        payload: blob.payload.clone(),
    });
    let body = msg.encode_payload();
    let cache = CodecCache::builtin();

    // the intact body decodes to the encoder's theta
    assert_eq!(decode_chain(&cache, &body).unwrap(), blob.theta);

    for cut in 0..body.len() {
        // no panic; and anything that still "decodes" must not silently
        // yield a full-length model (the driver's ensure_param_count
        // backstop is reachable only through length changes)
        match decode_chain(&cache, &body[..cut]) {
            None => {}
            Some(decoded) => assert_ne!(
                decoded, blob.theta,
                "cut at {cut}/{} decoded to the intact model",
                body.len()
            ),
        }
    }
}

#[test]
fn wire_header_survives_single_bit_flips() {
    let mut rng = Rng::new(0xB17); // bit flips
    let (theta, cents) = random_state(400, &mut rng);
    let reg = CodecRegistry::builtin();
    let pipe = reg.build("topk(keep=0.25)|kmeans(c=8,iters=10)|huffman").unwrap();
    let blob = pipe.encode(&input(&theta, &cents), &mut rng).unwrap();
    let spec = pipe.spec();

    let msg = Msg::Download(Download {
        round: 3,
        client: 1,
        spec: spec.clone(),
        payload: blob.payload.clone(),
    });
    let body = msg.encode_payload();
    let cache = CodecCache::builtin();

    // flip every bit of the codec header region: round(4) + client(4)
    // precede it; version(1) + spec_len(2) + spec follow
    let header_start = 8;
    let header_end = 8 + 3 + spec.len();
    for byte in header_start..header_end {
        for bit in 0..8 {
            let mut bad = body.clone();
            bad[byte] ^= 1 << bit;
            // typed error or a decode that differs from the intact
            // model — never a panic, never a silent identical "success"
            // under a corrupted header driving a different codec
            if let Some(decoded) = decode_chain(&cache, &bad) {
                assert_eq!(
                    decoded, blob.theta,
                    "flip {byte}:{bit} decoded differently without erroring"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// custom codec across a real TCP loopback socket
// ---------------------------------------------------------------------------

/// A downstream user codec the built-in set knows nothing about:
/// 1-bit sign compression at a per-blob scale.
/// Payload: `u32 n | f32 scale | sign bits (1 = negative)`.
struct SignStage;

impl Stage for SignStage {
    fn name(&self) -> &'static str {
        "signsgd"
    }
    fn spec(&self) -> String {
        "signsgd".to_string()
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Floats
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Floats
    }
    fn terminal_only(&self) -> bool {
        true
    }

    fn encode(
        &self,
        data: StageData,
        _input: &CodecInput<'_>,
        _rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        let StageData::Floats(v) = data else {
            return Err(CodecError::Malformed {
                what: "signsgd expects floats".to_string(),
            });
        };
        if v.is_empty() {
            return Err(CodecError::EmptyInput { stage: "signsgd" });
        }
        let scale = v.iter().map(|w| w.abs()).sum::<f32>() / v.len() as f32;
        Ok(StageData::Floats(
            v.iter().map(|w| if *w < 0.0 { -scale } else { scale }).collect(),
        ))
    }

    fn serialize(&self, data: &StageData, _input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        let StageData::Floats(v) = data else {
            return Err(CodecError::Malformed {
                what: "signsgd expects floats".to_string(),
            });
        };
        let scale = v.iter().find(|w| **w != 0.0).map(|w| w.abs()).unwrap_or(0.0);
        let mut out = Vec::with_capacity(8 + v.len().div_ceil(8));
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(&scale.to_le_bytes());
        let mut acc = 0u8;
        for (i, w) in v.iter().enumerate() {
            if *w < 0.0 {
                acc |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                out.push(acc);
                acc = 0;
            }
        }
        if v.len() % 8 != 0 {
            out.push(acc);
        }
        Ok(out)
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        if payload.len() < 8 {
            return Err(CodecError::Truncated { what: "signsgd header" });
        }
        let n = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
        let scale = f32::from_le_bytes(payload[4..8].try_into().unwrap());
        let bits = &payload[8..];
        if bits.len() != n.div_ceil(8) {
            return Err(CodecError::Malformed {
                what: format!("signsgd body is {} bytes for {n} params", bits.len()),
            });
        }
        let v: Vec<f32> = (0..n)
            .map(|i| {
                if bits[i / 8] >> (i % 8) & 1 == 1 {
                    -scale
                } else {
                    scale
                }
            })
            .collect();
        Ok(StageData::Floats(v))
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        Ok(data)
    }
}

fn registry_with_signsgd() -> CodecRegistry {
    let mut reg = CodecRegistry::builtin();
    reg.register(CodecInfo {
        name: "signsgd",
        aliases: &["sign"],
        description: "1-bit sign compression at a per-blob scale",
        ctor: |p| {
            p.ensure_known(&[])?;
            Ok(Box::new(SignStage))
        },
    })
    .unwrap();
    reg
}

/// The acceptance headline: a codec the built-in registry does not
/// know, registered on both ends, crosses a real TCP loopback socket
/// in both directions — the old `Opaque` path errored here by design.
#[test]
fn custom_codec_crosses_tcp_loopback_end_to_end() {
    let mut rng = Rng::new(0x516);
    let theta: Vec<f32> = (0..3000).map(|_| rng.normal() * 0.3).collect();

    // sender side: encode with the custom registry
    let sender = registry_with_signsgd();
    let pipe = sender.build("signsgd").unwrap();
    let blob = pipe.encode(&CodecInput::floats(&theta), &mut rng).unwrap();
    assert!(blob.payload.len() < dense_bytes(theta.len()) / 20, "1-bit wire");

    // real sockets, both directions
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let tx = TcpStream::connect(addr).unwrap();
    let (rx, _) = listener.accept().unwrap();

    write_download(&mut &tx, 2, 5, &pipe.spec(), &blob.payload).unwrap();
    write_upload(
        &mut &tx,
        &Upload {
            round: 2,
            client: 5,
            score: 1.5,
            n: 64,
            mean_ce: 0.25,
            mu: vec![0.0; 4],
            stages: blob.stage_bytes.clone(),
            spec: pipe.spec(),
            payload: blob.payload.clone(),
        },
    )
    .unwrap();

    // receiver side: its own registry instance resolves the spec
    let receiver = CodecCache::new(registry_with_signsgd());
    let dl = match Msg::read_from(&mut &rx).unwrap() {
        Msg::Download(d) => d,
        other => panic!("expected Download, got {}", other.kind()),
    };
    assert_eq!(dl.spec, "signsgd");
    let decoded = receiver.decode(&dl.spec, &dl.payload).unwrap();
    assert_eq!(decoded, blob.theta, "download direction");

    let up = match Msg::read_from(&mut &rx).unwrap() {
        Msg::Upload(u) => u,
        other => panic!("expected Upload, got {}", other.kind()),
    };
    assert_eq!(up.stages, blob.stage_bytes);
    let decoded = receiver.decode(&up.spec, &up.payload).unwrap();
    assert_eq!(decoded, blob.theta, "upload direction");

    // ...and it is the *registration* that makes it cross: the
    // built-in cache rejects the same spec with the typed error
    let builtin = CodecCache::builtin();
    let err = builtin.decode(&dl.spec, &dl.payload).unwrap_err().to_string();
    assert!(err.contains("unknown codec 'signsgd'"), "{err}");
}

// ---------------------------------------------------------------------------
// delta: cross-round loopback equivalence
// ---------------------------------------------------------------------------

/// Sender and receiver `delta` instances stay synchronized across a
/// multi-round exchange over a real loopback socket: every round's
/// decode reproduces the encoder's theta bit-exactly, and once the
/// stream has a baseline, residual blobs undercut the first (flat)
/// one by a wide margin.
#[test]
fn delta_streams_stay_loopback_equivalent_across_rounds() {
    let mut rng = Rng::new(0xDE17A);
    let (mut theta, cents) = random_state(4000, &mut rng);
    let reg = CodecRegistry::builtin();
    let sender = reg.build("codebook|delta").unwrap();
    let receiver = CodecCache::builtin();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let tx = TcpStream::connect(addr).unwrap();
    let (rx, _) = listener.accept().unwrap();

    let mut sizes = Vec::new();
    for round in 0..6u32 {
        // slow drift: ~1% of entries move a little each round
        for _ in 0..theta.len() / 100 {
            let i = rng.below(theta.len());
            theta[i] += 0.01 * rng.normal();
        }
        let enc_input = CodecInput {
            theta: &theta,
            centroids: Some(&cents),
            stream: stream::upload(7),
        };
        let blob = sender.encode(&enc_input, &mut rng).unwrap();
        write_download(&mut &tx, round, 7, &sender.spec(), &blob.payload).unwrap();

        let dl = match Msg::read_from(&mut &rx).unwrap() {
            Msg::Download(d) => d,
            other => panic!("expected Download, got {}", other.kind()),
        };
        let decoded = receiver.decode(&dl.spec, &dl.payload).unwrap();
        assert_eq!(decoded, blob.theta, "round {round} diverged");
        sizes.push(blob.payload.len());
    }
    for (round, &s) in sizes.iter().enumerate().skip(1) {
        assert!(
            s < sizes[0] / 2,
            "round {round}: residual blob {s} B should undercut the flat {} B",
            sizes[0]
        );
    }
}

/// Residual blobs are refused — with a typed error, not garbage —
/// by a receiver that never saw the stream's baseline, and streams
/// are independent of each other.
#[test]
fn delta_desync_is_a_typed_error_and_streams_are_independent() {
    let mut rng = Rng::new(0xDE5);
    let (theta, cents) = random_state(1000, &mut rng);
    let reg = CodecRegistry::builtin();
    let sender = reg.build("codebook|delta").unwrap();

    let enc = |theta: &[f32], sid: u64, rng: &mut Rng| {
        sender
            .encode(
                &CodecInput {
                    theta,
                    centroids: Some(&cents),
                    stream: sid,
                },
                rng,
            )
            .unwrap()
    };

    // stream 1: two rounds (second is a residual); stream 2 interleaves
    let first = enc(&theta, 1, &mut rng);
    let mut drifted = theta.clone();
    drifted[3] += 0.5;
    let other = enc(&theta, 2, &mut rng);
    let second = enc(&drifted, 1, &mut rng);
    assert!(second.payload.len() < first.payload.len());

    // a synchronized receiver follows both streams in any interleaving
    let receiver = reg.build("codebook|delta").unwrap();
    assert_eq!(receiver.decode(&first.payload).unwrap(), first.theta);
    assert_eq!(receiver.decode(&other.payload).unwrap(), other.theta);
    assert_eq!(receiver.decode(&second.payload).unwrap(), second.theta);

    // a cold receiver rejects the residual blob loudly
    let cold = reg.build("codebook|delta").unwrap();
    let err = cold.decode(&second.payload).unwrap_err().to_string();
    assert!(err.contains("no baseline"), "{err}");
}

/// The delta stage keeps its per-stream baselines in an ordered map
/// (`fedlint: det-map-iter`): the bytes a stream produces depend only
/// on that stream's own history, never on which *other* streams the
/// pipeline has seen or in what order they arrived. Two senders fed
/// the same per-stream sequences in opposite interleavings must emit
/// bit-identical blobs.
#[test]
fn delta_stream_state_is_arrival_order_independent() {
    let mut rng = Rng::new(0x0A0B);
    let (theta_a, cents) = random_state(800, &mut rng);
    let mut theta_b = theta_a.clone();
    for i in (0..theta_b.len()).step_by(7) {
        theta_b[i] += 0.3;
    }
    let mut drift_a = theta_a.clone();
    drift_a[5] += 0.4;
    let mut drift_b = theta_b.clone();
    drift_b[11] -= 0.4;

    let reg = CodecRegistry::builtin();
    let enc = |p: &fedcompress::codec::Pipeline, theta: &[f32], sid: u64| {
        let inp = CodecInput {
            theta,
            centroids: Some(&cents),
            stream: sid,
        };
        p.encode(&inp, &mut Rng::new(0)).unwrap().payload
    };

    // sender 1 sees stream 10 first, sender 2 sees stream 20 first
    let s1 = reg.build("codebook|delta").unwrap();
    let a1 = enc(&s1, &theta_a, 10);
    let b1 = enc(&s1, &theta_b, 20);
    let a2 = enc(&s1, &drift_a, 10);
    let b2 = enc(&s1, &drift_b, 20);

    let s2 = reg.build("codebook|delta").unwrap();
    let b1x = enc(&s2, &theta_b, 20);
    let a1x = enc(&s2, &theta_a, 10);
    let b2x = enc(&s2, &drift_b, 20);
    let a2x = enc(&s2, &drift_a, 10);

    assert_eq!(a1, a1x, "stream 10 round 1 depends on arrival order");
    assert_eq!(a2, a2x, "stream 10 round 2 depends on arrival order");
    assert_eq!(b1, b1x, "stream 20 round 1 depends on arrival order");
    assert_eq!(b2, b2x, "stream 20 round 2 depends on arrival order");

    // and a receiver reading the opposite interleaving still follows
    let recv = reg.build("codebook|delta").unwrap();
    assert_eq!(recv.decode(&b1).unwrap().len(), theta_b.len());
    assert_eq!(recv.decode(&a1).unwrap().len(), theta_a.len());
    assert_eq!(recv.decode(&b2).unwrap().len(), theta_b.len());
    assert_eq!(recv.decode(&a2).unwrap().len(), theta_a.len());
}

/// Wire-claimed element counts are capped (`MAX_PARAMS`) before any
/// allocation happens: a 4-billion-param claim in a 20-byte blob is a
/// typed error, not an OOM.
#[test]
fn hostile_param_counts_are_refused_before_allocation() {
    use fedcompress::codec::stages::{sparse_decode, MAX_PARAMS};

    // sparse: magic | n | k | bits | positions | values
    let mut bad = Vec::new();
    bad.extend_from_slice(&0x4643_5331u32.to_le_bytes());
    bad.extend_from_slice(&u32::MAX.to_le_bytes());
    bad.extend_from_slice(&0u32.to_le_bytes());
    bad.push(32);
    let err = sparse_decode(&bad).unwrap_err().to_string();
    assert!(err.contains("cap"), "{err}");
    assert!((MAX_PARAMS as u64) < u64::from(u32::MAX));

    // delta: stream | c | codebook | n | mode | body
    let reg = CodecRegistry::builtin();
    let p = reg.build("codebook|delta").unwrap();
    let mut bad = Vec::new();
    bad.extend_from_slice(&1u64.to_le_bytes());
    bad.extend_from_slice(&1u16.to_le_bytes());
    bad.extend_from_slice(&0.5f32.to_le_bytes());
    bad.extend_from_slice(&u32::MAX.to_le_bytes());
    bad.push(0);
    let err = p.decode(&bad).unwrap_err().to_string();
    assert!(err.contains("cap"), "{err}");
}
