//! no-wallclock-state fixture. Expected (scoped as src/fake/):
//!   deny hits on lines 8, 9; line 14 suppressed by line 13.
//!   Imports and type positions never trip the rule.

use std::time::{Duration, Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    let t = Instant::now();
    let s = SystemTime::now();
    (t, s)
}

// fedlint:allow(no-wallclock-state) -- created_unix is an environment field
pub fn created() -> SystemTime { SystemTime::now() }

pub fn span() -> Duration { Duration::from_secs(1) }
