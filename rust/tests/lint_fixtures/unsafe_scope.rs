//! unsafe-scope fixture. Expected (scoped as src/fake/):
//!   deny hits on lines 6 and 9; line 13 suppressed by line 12.
//!   String literals and #[cfg(test)] modules never trip the rule.

pub fn raw_read(p: *const u32) -> u32 {
    unsafe { p.read() }
}

pub unsafe fn lane_load(p: *const u32) -> u32 {
    p.read()
}

// fedlint:allow(unsafe-scope) -- pointer proven in-bounds by the caller's loop
pub fn sanctioned(p: *const u32) -> u32 { unsafe { p.read() } }

pub fn named() -> &'static str { "unsafe" }

#[cfg(test)]
mod tests {
    pub fn t(p: *const u32) -> u32 { unsafe { p.read() } }
}
