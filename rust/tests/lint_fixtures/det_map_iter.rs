//! det-map-iter fixture. Expected (scoped as src/fake/):
//!   deny hits on lines 6, 7, 13; line 10 suppressed by line 9.
//!   The test module at the bottom is exempt.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::collections::HashSet;

// fedlint:allow(det-map-iter) -- perf-only cache, never iterated
pub struct Cache(HashMap<u64, u64>);

pub fn build() -> (Cache, BTreeMap<u64, u64>) {
    (Cache(HashMap::new()), BTreeMap::new())
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    #[test]
    fn hash_in_tests_is_fine() {
        let _ = HashSet::<u8>::new();
    }
}
