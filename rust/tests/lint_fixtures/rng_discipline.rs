//! rng-discipline fixture. Expected (scoped as src/fake/):
//!   deny hit on line 6; line 12 suppressed by line 11.
//!   Forks and borrowed Rng parameters never trip the rule.

pub fn ad_hoc() -> Rng {
    Rng::new(1234)
}

pub fn forked(base: &Rng) -> Rng { base.fork(7) }

// fedlint:allow(rng-discipline) -- the named per-run root constructor
pub fn run_root(seed: u64) -> Rng { Rng::new(seed ^ 0xFEDC) }
