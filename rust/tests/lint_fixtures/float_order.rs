//! float-order fixture. Expected (scoped as src/fake/):
//!   deny hits on lines 6, 8; line 13 suppressed by line 12.
//!   Widening casts and f64 reductions never trip the rule.

pub fn narrow(x: f64) -> f32 {
    x as f32
}
pub fn total(v: &[f32]) -> f32 { v.iter().sum::<f32>() }

pub fn wide(v: &[f32]) -> f64 { v.iter().map(|&x| x as f64).sum::<f64>() }

// fedlint:allow(float-order) -- accumulated in f64, narrowed exactly once
pub fn narrow_once(acc: f64) -> f32 { acc as f32 }
