//! Clean fixture: every rule passes — ordered maps, option-based
//! access, forked rngs, f64 accumulation.

use std::collections::BTreeMap;

pub fn sum64(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64).sum::<f64>()
}

pub fn first(buf: &[u8]) -> Option<u8> {
    buf.first().copied()
}

pub fn ordered(base: &Rng) -> (BTreeMap<u64, u64>, Rng) {
    (BTreeMap::new(), base.fork(1))
}
