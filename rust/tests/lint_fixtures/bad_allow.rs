//! bad-allow fixture: broken suppressions must never suppress.
//!   line 5: allow without a reason  (bad-allow, deny)
//!   line 8: allow naming an unknown rule  (bad-allow, deny)
//!   line 11: well-formed but stale  (unused-allow, warn)
// fedlint:allow(det-map-iter)
use std::collections::BTreeMap;

// fedlint:allow(not-a-rule) -- misspelled rule name
pub fn f() -> BTreeMap<u8, u8> { BTreeMap::new() }

// fedlint:allow(det-map-iter) -- nothing on the next line violates it
pub fn g() {}
