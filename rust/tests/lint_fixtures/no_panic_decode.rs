//! no-panic-decode fixture. Expected (scoped as src/fake/):
//!   deny hits on lines 6, 7, 8, 10, 12; line 16 suppressed by line 15.
//!   Slice patterns, array types, and test code never trip the rule.

pub fn decode(buf: &[u8]) -> u8 {
    let first = buf[0];
    let second = buf.get(1).unwrap();
    let third = buf.get(2).expect("third byte");
    if buf.len() > 9 {
        panic!("oversized");
    }
    unreachable!()
}

// fedlint:allow(no-panic-decode) -- index bounded by the fixed array type
pub fn bounded(buf: &[u8; 4]) -> u8 { buf[1] }

pub fn safe(buf: &[u8]) -> Option<u8> {
    let [_a, _b] = [0u8; 2];
    buf.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v = [1u8];
        assert_eq!(*v.first().unwrap(), 1);
    }
}
