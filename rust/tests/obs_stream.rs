//! Observability contract tests: the teed event stream, the offline
//! replay synthesized from a stored [`RunRecord`], and the live
//! [`FileSink`] serialization path must all agree byte-for-byte, and
//! the tolerant parser must survive arbitrary corruption — truncation
//! at every byte offset and single-bit flips — without panicking.
//! Everything here runs on the engine-free `SmokeRunner`; the
//! engine-gated case at the bottom proves the same contract for a real
//! in-process training run with a live tee attached.

use std::path::{Path, PathBuf};

use fedcompress::baselines::registry::StrategyRegistry;
use fedcompress::config::FedConfig;
use fedcompress::obs::sink::{BoundedSink, EventSink, FileSink};
use fedcompress::obs::stream::{
    parse_stream, record_stream_events, render_stream, StreamEvent, StreamHeader,
};
use fedcompress::obs::view::RunView;
use fedcompress::store::{key_hex, RunStore};
use fedcompress::sweep::{run_sweep, SmokeRunner, SweepEvent, SweepSpec};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("fedcompress_obs_stream")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quiet(_: SweepEvent) {}

fn grid(strategies: &[&str]) -> (FedConfig, SweepSpec) {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = 4;
    let spec = SweepSpec {
        strategies: strategies.iter().map(|s| s.to_string()).collect(),
        seeds: vec![41],
        ..SweepSpec::default()
    };
    (cfg, spec)
}

/// Smoke-sweep the given strategies into `<dir>/store`, teeing one
/// stream file per job into `<dir>/store/events`.
fn sweep_into(dir: &Path, strategies: &[&str]) -> (RunStore, PathBuf) {
    let (cfg, spec) = grid(strategies);
    let jobs = spec.expand(&cfg, &StrategyRegistry::builtin()).unwrap();
    let mut store = RunStore::open(&dir.join("store")).unwrap();
    let events_dir = dir.join("store").join("events");
    run_sweep(&jobs, &mut store, &SmokeRunner, 4, false, Some(&events_dir), &quiet).unwrap();
    (store, events_dir)
}

fn stream_path(events_dir: &Path, key: u64) -> PathBuf {
    events_dir.join(format!("{}.jsonl", key_hex(key)))
}

/// One small teed stream (single strategy, single seed) as corruption
/// fodder for the fuzz tests.
fn demo_stream(name: &str) -> String {
    let dir = tmp(name);
    let (store, events_dir) = sweep_into(&dir, &["fedcompress"]);
    let key = store.keys()[0];
    std::fs::read_to_string(stream_path(&events_dir, key)).unwrap()
}

/// The headline guarantee, per registered strategy: the sweep's teed
/// stream file, the replay synthesized from the stored record, and the
/// live `FileSink` serialization of the same events are byte-identical,
/// and all of them render into the same error-free `runs tail` view.
#[test]
fn teed_stream_matches_record_replay_for_every_strategy() {
    let dir = tmp("replay_equality");
    let all = StrategyRegistry::builtin().names();
    let (store, events_dir) = sweep_into(&dir, &all);
    let keys = store.keys();
    assert_eq!(keys.len(), all.len());
    for key in keys {
        let rec = store.get(key).unwrap().unwrap();
        let teed = std::fs::read_to_string(stream_path(&events_dir, key)).unwrap();

        // offline synthesis from the stored record
        let (events, errors) = record_stream_events(&rec);
        assert!(errors.is_empty(), "key {}", key_hex(key));
        let synthesized = render_stream(&StreamHeader::for_record(&rec), &events);
        assert_eq!(teed, synthesized, "key {}", key_hex(key));

        // the live-sink path: emitting the same events through a
        // FileSink (bounded channel + writer thread) must serialize to
        // the identical bytes, seq stamping included
        let live_path = dir.join("live").join(format!("{}.jsonl", key_hex(key)));
        let sink = FileSink::create(&live_path, &StreamHeader::for_record(&rec), 4096).unwrap();
        for e in &events {
            sink.emit(e);
        }
        assert_eq!(sink.finish().unwrap(), 0);
        let lived = std::fs::read_to_string(&live_path).unwrap();
        assert_eq!(lived, synthesized, "key {}", key_hex(key));

        // both replay into the same rendered view, error-free
        let replay = parse_stream(&teed);
        assert!(replay.errors.is_empty(), "key {}", key_hex(key));
        let view = RunView::from_replay(&replay).render();
        let live_view = RunView::from_replay(&parse_stream(&lived)).render();
        assert_eq!(view, live_view);
        assert!(view.contains("final round"), "{view}");
        assert!(view.contains("0 parse error"), "{view}");
        assert!(view.contains(&key_hex(key)), "{view}");
    }
}

/// A fully cached re-sweep executes nothing but still restores a
/// deleted stream file (and leaves the surviving ones byte-identical).
#[test]
fn cached_sweep_restores_missing_tee_files() {
    let dir = tmp("cache_tee");
    let (mut store, events_dir) = sweep_into(&dir, &["fedavg", "fedcompress"]);
    let keys = store.keys();
    let victim = stream_path(&events_dir, keys[0]);
    let survivor = stream_path(&events_dir, keys[1]);
    let survivor_before = std::fs::read_to_string(&survivor).unwrap();
    std::fs::remove_file(&victim).unwrap();

    let (cfg, spec) = grid(&["fedavg", "fedcompress"]);
    let jobs = spec.expand(&cfg, &StrategyRegistry::builtin()).unwrap();
    let out = run_sweep(&jobs, &mut store, &SmokeRunner, 2, false, Some(&events_dir), &quiet)
        .unwrap();
    assert_eq!(out.executed, 0, "cache must absorb every job");
    assert_eq!(out.cached, 2);

    let rec = store.get(keys[0]).unwrap().unwrap();
    let restored = std::fs::read_to_string(&victim).unwrap();
    let (events, _) = record_stream_events(&rec);
    assert_eq!(restored, render_stream(&StreamHeader::for_record(&rec), &events));
    assert_eq!(std::fs::read_to_string(&survivor).unwrap(), survivor_before);
}

/// Truncation at *every* byte offset: the parser and the view renderer
/// must never panic, whatever half-line the cut leaves behind.
#[test]
fn parse_and_render_survive_truncation_at_every_byte_offset() {
    let text = demo_stream("truncate");
    let bytes = text.as_bytes();
    assert!(bytes.len() > 200, "fixture unexpectedly small");
    for cut in 0..=bytes.len() {
        let s = String::from_utf8_lossy(&bytes[..cut]);
        let replay = parse_stream(&s);
        let _ = RunView::from_replay(&replay).render();
    }
}

/// Single-bit flips anywhere in the stream: damage stays per-line —
/// counted, never fatal, never a panic.
#[test]
fn parse_and_render_survive_single_bit_flips() {
    let text = demo_stream("bitflip");
    let bytes = text.as_bytes().to_vec();
    for i in 0..bytes.len() {
        let mut mutated = bytes.clone();
        mutated[i] ^= 1 << (i % 8);
        let s = String::from_utf8_lossy(&mutated);
        let replay = parse_stream(&s);
        assert!(replay.errors.len() <= s.lines().count());
        let _ = RunView::from_replay(&replay).render();
    }
}

/// Garbage lines appended to a clean stream surface as per-line parse
/// errors in the rendered view; every valid event still replays.
#[test]
fn corrupt_lines_are_counted_not_fatal() {
    let text = demo_stream("garbage");
    let clean = parse_stream(&text);
    assert!(clean.errors.is_empty());
    let n = clean.events.len();

    let dirty = format!("{text}not json at all\n{{\"kind\":\"from_the_future\"}}\n");
    let replay = parse_stream(&dirty);
    assert_eq!(replay.events.len(), n, "valid events must all survive");
    assert_eq!(replay.errors.len(), 2);
    let view = RunView::from_replay(&replay).render();
    assert!(view.contains("2 parse error(s)"), "{view}");
    assert!(view.contains("final round"), "{view}");
}

/// A second EVNT1 header mid-stream is an error line, not a header
/// swap: the first identity wins.
#[test]
fn duplicate_header_is_rejected_per_line() {
    let text = demo_stream("dup_header");
    let header_line = text.lines().next().unwrap().to_string();
    let dirty = format!("{text}{header_line}\n");
    let replay = parse_stream(&dirty);
    assert_eq!(replay.errors.len(), 1);
    assert!(replay.errors[0].error.contains("extra stream header"));
    let first = parse_stream(&text).header.unwrap();
    assert_eq!(replay.header.unwrap().run, first.run);
}

/// The non-blocking contract through the public API: with nothing
/// draining the channel, every emit past capacity returns immediately
/// and increments the drop counter; seq keeps advancing so readers see
/// the loss as a gap.
#[test]
fn bounded_sink_overflow_drops_without_blocking() {
    let (tx, rx) = std::sync::mpsc::sync_channel(2);
    let sink = BoundedSink::new(tx);
    for round in 0..10 {
        sink.emit(&StreamEvent::RoundOps {
            round,
            stragglers: 0,
            peak_parked: 0,
            sim_ms: 0.0,
        });
    }
    assert_eq!(sink.offered(), 10);
    assert_eq!(sink.dropped(), 8);
    let delivered: Vec<String> = rx.try_iter().collect();
    assert_eq!(delivered.len(), 2);
    let replay = parse_stream(&delivered.join("\n"));
    assert!(replay.errors.is_empty());
    assert_eq!(replay.events.len(), 2);
}

// ---------------------------------------------------------------------------
// engine-gated: a real run with a live tee attached
// ---------------------------------------------------------------------------

fn engine() -> Option<fedcompress::runtime::Engine> {
    let d = fedcompress::runtime::artifacts::default_dir();
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(fedcompress::runtime::Engine::load(&d).unwrap())
}

/// The acceptance criterion on a real training run: the stream teed
/// live during `run_with_strategy_sink` equals the replay synthesized
/// from the stored record, byte for byte, once the live-only transport
/// detail is set aside — the per-slot forensic lines, and the parked
/// reorder peak inside `round_ops` (the record deliberately keeps
/// neither; replay zeroes the peak).
#[test]
fn live_tee_equals_record_replay_for_a_real_run() {
    let Some(engine) = engine() else { return };
    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = 3;
    cfg.clients = 3;
    cfg.local_epochs = 2;
    cfg.server_epochs = 1;
    cfg.train_size = 192;
    cfg.test_size = 96;
    cfg.ood_size = 64;
    cfg.unlabeled_per_client = 16;
    cfg.warmup_rounds = 1;
    cfg.validate().unwrap();

    let dir = tmp("live_tee");
    let key = fedcompress::store::run_key("fedavg", &cfg);
    let live_path = dir.join("events").join(format!("{}.jsonl", key_hex(key)));
    let header = StreamHeader::new(key, &cfg, "fedavg");
    let sink = FileSink::create(&live_path, &header, 4096).unwrap();

    let mut plugin = StrategyRegistry::builtin().build("fedavg", &cfg).unwrap();
    let data = fedcompress::coordinator::server::build_data(&engine, &cfg).unwrap();
    let mut transport = fedcompress::net::InProcess;
    let result = fedcompress::coordinator::run_with_strategy_sink(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        None,
        &sink,
    )
    .unwrap();
    assert_eq!(sink.finish().unwrap(), 0);

    let rec = fedcompress::store::RunRecord::from_result(&cfg, &result);
    assert_eq!(rec.key, key);
    let (events, errors) = record_stream_events(&rec);
    assert!(errors.is_empty());
    let synthesized = render_stream(&StreamHeader::for_record(&rec), &events);

    let live_text = std::fs::read_to_string(&live_path).unwrap();
    let live = parse_stream(&live_text);
    assert!(live.errors.is_empty());
    // the live stream additionally carries per-slot arrival lines and
    // per-round phase_timing profiles (both live-only by contract),
    // and its round_ops report the reorder window's real high-water
    // mark (≥ 1 whenever anything uploaded); everything else — order,
    // values, round_ops placement — matches
    let canonical: Vec<StreamEvent> = live
        .events
        .iter()
        .filter(|e| {
            !matches!(
                e,
                StreamEvent::Slot { .. } | StreamEvent::PhaseTiming { .. }
            )
        })
        .map(|e| match e {
            StreamEvent::RoundOps {
                round,
                stragglers,
                sim_ms,
                ..
            } => StreamEvent::RoundOps {
                round: *round,
                stragglers: *stragglers,
                peak_parked: 0,
                sim_ms: *sim_ms,
            },
            other => other.clone(),
        })
        .collect();
    assert!(live.events.len() > canonical.len(), "slot lines expected");
    let refiltered = render_stream(&StreamHeader::for_record(&rec), &canonical);
    assert_eq!(refiltered, synthesized);

    // the normalized live stream and the record replay render the same
    // view, and the live view itself names the final round
    let norm = fedcompress::obs::stream::StreamReplay {
        header: live.header.clone(),
        events: canonical,
        errors: Vec::new(),
    };
    let norm_view = RunView::from_replay(&norm).render();
    let replay_view = RunView::from_replay(&parse_stream(&synthesized)).render();
    assert_eq!(norm_view, replay_view);
    let live_view = RunView::from_replay(&live).render();
    assert!(live_view.contains(&format!("final round {}", cfg.rounds - 1)));
}
