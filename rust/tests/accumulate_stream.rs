//! The streaming-fold determinism contract, as a property test: for
//! every registered strategy, folding a round's uploads through
//! [`StreamAccumulator`] produces a bit-identical `AggOutput` under
//! EVERY arrival order — identity, reversed, and a battery of seeded
//! shuffles — because the accumulator parks out-of-order uploads and
//! folds strictly in canonical (client-id-sorted) slot order. No
//! engine needed: updates are synthetic vectors.

use fedcompress::baselines::registry::StrategyRegistry;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::accumulate::{AggFold, AggOutput, FedAvgFold, StreamAccumulator};
use fedcompress::coordinator::aggregate::{fedavg, weighted_mean};
use fedcompress::coordinator::server::run_rng;
use fedcompress::coordinator::strategy::{ClientUpdate, RoundContext};
use fedcompress::util::rng::Rng;

const PARAMS: usize = 97;
const C_MAX: usize = 8;
const SLOTS: usize = 13;

/// One synthetic round: per-slot either an upload (Some) or a loss
/// (None). Client ids are the slot indices — already canonical.
fn round_updates() -> Vec<Option<ClientUpdate>> {
    let mut rng = Rng::new(0xACC);
    (0..SLOTS)
        .map(|slot| {
            // slots 3 and 9 are losses (dropout / deadline / eviction)
            if slot == 3 || slot == 9 {
                return None;
            }
            Some(ClientUpdate {
                client: slot,
                theta: (0..PARAMS).map(|_| rng.normal()).collect(),
                mu: (0..C_MAX).map(|_| rng.normal()).collect(),
                score: rng.f64(),
                n: 5 + rng.below(60),
            })
        })
        .collect()
}

/// Arrival orders: identity, reversed, and seeded shuffles.
fn arrival_orders() -> Vec<Vec<usize>> {
    let mut orders = vec![
        (0..SLOTS).collect::<Vec<_>>(),
        (0..SLOTS).rev().collect::<Vec<_>>(),
    ];
    for seed in 0..40u64 {
        let mut order: Vec<usize> = (0..SLOTS).collect();
        Rng::new(seed).shuffle(&mut order);
        orders.push(order);
    }
    orders
}

/// Drive one accumulator over the round in the given arrival order.
fn stream_in_order(
    fold: Box<dyn AggFold>,
    updates: &[Option<ClientUpdate>],
    order: &[usize],
) -> AggOutput {
    let mut acc = StreamAccumulator::new(fold, updates.len());
    for &slot in order {
        match &updates[slot] {
            Some(up) => acc.resolve_upload(slot, up.clone()).unwrap(),
            None => acc.resolve_lost(slot).unwrap(),
        }
    }
    acc.finish().unwrap()
}

fn assert_bit_identical(a: &AggOutput, b: &AggOutput, what: &str) {
    assert_eq!(a.theta.len(), b.theta.len(), "{what}: theta length");
    for (i, (x, y)) in a.theta.iter().zip(&b.theta).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: theta[{i}]");
    }
    assert_eq!(a.mu.len(), b.mu.len(), "{what}: mu length");
    for (i, (x, y)) in a.mu.iter().zip(&b.mu).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: mu[{i}]");
    }
    assert_eq!(a.score.to_bits(), b.score.to_bits(), "{what}: score");
    assert_eq!(a.clients, b.clients, "{what}: clients");
    assert_eq!(a.total_n, b.total_n, "{what}: total_n");
}

/// The headline property, against the buffered reference: streaming
/// FedAvg == `fedavg`/`weighted_mean` over the survivor set,
/// bit-for-bit, for every arrival order.
#[test]
fn streaming_fedavg_matches_buffered_fedavg_under_every_arrival_order() {
    let updates = round_updates();
    let survivors: Vec<&ClientUpdate> = updates.iter().flatten().collect();
    let thetas: Vec<Vec<f32>> = survivors.iter().map(|u| u.theta.clone()).collect();
    let mus: Vec<Vec<f32>> = survivors.iter().map(|u| u.mu.clone()).collect();
    let ns: Vec<usize> = survivors.iter().map(|u| u.n).collect();
    let scores: Vec<f64> = survivors.iter().map(|u| u.score).collect();
    let buffered_theta = fedavg(&thetas, &ns).unwrap();
    let buffered_mu = fedavg(&mus, &ns).unwrap();
    let buffered_score = weighted_mean(&scores, &ns).unwrap();

    for order in arrival_orders() {
        let out = stream_in_order(Box::new(FedAvgFold::new()), &updates, &order);
        assert_eq!(out.clients, survivors.len());
        assert_eq!(out.total_n, ns.iter().sum::<usize>());
        for (i, (x, y)) in out.theta.iter().zip(&buffered_theta).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "theta[{i}] under {order:?}");
        }
        for (i, (x, y)) in out.mu.iter().zip(&buffered_mu).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "mu[{i}] under {order:?}");
        }
        assert_eq!(out.score.to_bits(), buffered_score.to_bits(), "under {order:?}");
    }
}

/// Every registered strategy's fold — whatever reduction it implements
/// — is arrival-order-invariant through the accumulator: shuffled
/// arrival bit-matches canonical arrival.
#[test]
fn every_strategy_fold_is_arrival_order_invariant() {
    let cfg = FedConfig::quick("cifar10");
    let base = run_rng(&cfg);
    let ctx = RoundContext {
        round: 2,
        cfg: &cfg,
        base: &base,
        compressing: true,
        down_compressed: true,
    };
    let updates = round_updates();
    let canonical: Vec<usize> = (0..SLOTS).collect();

    for name in StrategyRegistry::builtin().names() {
        let strategy = StrategyRegistry::builtin().build(name, &cfg).unwrap();
        let reference = stream_in_order(strategy.make_fold(&ctx), &updates, &canonical);
        for order in arrival_orders() {
            let out = stream_in_order(strategy.make_fold(&ctx), &updates, &order);
            assert_bit_identical(&out, &reference, &format!("{name} under {order:?}"));
        }
    }
}

/// The reorder window: canonical arrival never parks; fully reversed
/// arrival parks everything but the last slot.
#[test]
fn peak_parked_tracks_the_reorder_window() {
    let updates = round_updates();

    let mut acc = StreamAccumulator::new(Box::new(FedAvgFold::new()), updates.len());
    for slot in 0..SLOTS {
        match &updates[slot] {
            Some(up) => acc.resolve_upload(slot, up.clone()).unwrap(),
            None => acc.resolve_lost(slot).unwrap(),
        }
    }
    assert_eq!(acc.peak_parked(), 0, "in-order arrival must not park");
    acc.finish().unwrap();

    let mut acc = StreamAccumulator::new(Box::new(FedAvgFold::new()), updates.len());
    for slot in (0..SLOTS).rev() {
        match &updates[slot] {
            Some(up) => acc.resolve_upload(slot, up.clone()).unwrap(),
            None => acc.resolve_lost(slot).unwrap(),
        }
    }
    // every upload after slot 0 is held until slot 0 lands (losses
    // are marked, not parked — they carry no payload)
    let late_uploads = updates[1..].iter().flatten().count();
    assert_eq!(
        acc.peak_parked(),
        late_uploads,
        "reversed arrival parks every later upload until slot 0 lands"
    );
    acc.finish().unwrap();
}
