//! Run-store persistence tests: bit-exact record round-trips, index
//! rebuild from the file alone, and a corruption suite mirroring
//! `net_proto.rs` — truncations at every cut point and bit flips at
//! every byte must surface typed `StoreError`s, never panics.
//!
//! No engine needed anywhere here: records come from the sweep's
//! `SmokeRunner`, which fabricates deterministic measurement records
//! without PJRT.

use std::path::PathBuf;

use fedcompress::config::FedConfig;
use fedcompress::store::{
    diff_records, key_hex, run_key, RunRecord, RunStore, StoreError, FORMAT_VERSION,
};
use fedcompress::sweep::{JobRunner, SmokeRunner, SweepJob};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("fedcompress_store_roundtrip")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A deterministic record, no engine required.
fn rec(strategy: &str, seed: u64) -> RunRecord {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.seed = seed;
    cfg.rounds = 5;
    let job = SweepJob {
        idx: 0,
        strategy: strategy.to_string(),
        cfg: cfg.clone(),
        key: run_key(strategy, &cfg),
    };
    SmokeRunner.run(&job).unwrap()
}

#[test]
fn record_serialization_is_a_fixpoint() {
    let r = rec("fedcompress", 11);
    let body = r.to_body_bytes();
    let back = RunRecord::from_body_bytes(&body).unwrap();
    assert_eq!(back.to_body_bytes(), body);
    assert!(diff_records(&r, &back).is_identical());
    // the config image reconstructs the exact experiment
    let cfg = back.cfg().unwrap();
    assert_eq!(cfg.seed, 11);
    assert_eq!(back.key, run_key("fedcompress", &cfg));
}

#[test]
fn store_round_trips_across_reopen() {
    let dir = tmp("reopen");
    let (a, b) = (rec("fedavg", 1), rec("topk", 2));
    {
        let mut store = RunStore::open(&dir).unwrap();
        store.append(&a).unwrap();
        store.append(&b).unwrap();
        assert_eq!(store.len(), 2);
    }
    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.len(), 2);
    for r in [&a, &b] {
        let got = store.get(r.key).unwrap().unwrap();
        assert!(diff_records(r, &got).is_identical(), "{}", key_hex(r.key));
    }
    // metas carry the summary a listing needs
    let metas = store.latest();
    assert_eq!(metas.len(), 2);
    assert!(metas.iter().any(|m| m.strategy == "fedavg" && m.seed == 1));
    assert!(metas.iter().all(|m| m.rounds == 5 && m.total_bytes > 0));
}

#[test]
fn index_is_derived_from_the_file_alone() {
    let dir = tmp("index_rebuild");
    let a = rec("fedzip", 3);
    {
        let mut store = RunStore::open(&dir).unwrap();
        store.append(&a).unwrap();
    }
    // deleting the sidecar costs nothing
    std::fs::remove_file(dir.join("index.json")).unwrap();
    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.len(), 1);
    assert!(dir.join("index.json").exists(), "sidecar rewritten");
    // corrupting the sidecar costs nothing either (it is never read)
    std::fs::write(dir.join("index.json"), b"{not json").unwrap();
    let store = RunStore::open(&dir).unwrap();
    assert!(store.get(a.key).unwrap().is_some());
}

/// Truncating the record file at *every* byte offset must yield either
/// a typed error or a clean store with fewer records (when the cut
/// lands exactly on an entry boundary) — never a panic.
#[test]
fn truncation_at_every_cut_point_is_typed() {
    let dir = tmp("truncate_src");
    let (a, b) = (rec("fedavg", 4), rec("fedcompress", 5));
    let boundaries = {
        let mut store = RunStore::open(&dir).unwrap();
        store.append(&a).unwrap();
        store.append(&b).unwrap();
        let metas = store.metas();
        vec![
            metas[0].offset as usize,
            metas[1].offset as usize,
            metas[1].offset as usize + metas[1].entry_len,
        ]
    };
    let bytes = std::fs::read(dir.join("runs.fcr")).unwrap();
    assert_eq!(*boundaries.last().unwrap(), bytes.len());

    let cut_dir = tmp("truncate_cut");
    std::fs::create_dir_all(&cut_dir).unwrap();
    for cut in 0..bytes.len() {
        std::fs::write(cut_dir.join("runs.fcr"), &bytes[..cut]).unwrap();
        match RunStore::open(&cut_dir) {
            Ok(store) => {
                // only legal at an entry boundary (or bare header)
                let expected = match cut {
                    8 => 0,
                    c if c == boundaries[1] => 1,
                    c if c == boundaries[2] => 2,
                    other => panic!("truncation at {other} silently accepted"),
                };
                assert_eq!(store.len(), expected, "cut at {cut}");
            }
            Err(
                StoreError::Truncated { .. }
                | StoreError::BadMagic { .. }
                | StoreError::UnsupportedVersion { .. }
                | StoreError::Oversized { .. }
                | StoreError::ChecksumMismatch { .. },
            ) => {}
            Err(other) => panic!("cut at {cut}: unexpected error kind {other:?}"),
        }
    }
}

/// Flipping any single byte of the store must surface a typed error
/// (header fields, entry framing, body bytes, checksums — everything
/// is covered by magic, caps, or FNV).
#[test]
fn every_bit_flip_is_detected() {
    let dir = tmp("bitflip_src");
    let a = rec("topk", 6);
    {
        let mut store = RunStore::open(&dir).unwrap();
        store.append(&a).unwrap();
    }
    let bytes = std::fs::read(dir.join("runs.fcr")).unwrap();
    let flip_dir = tmp("bitflip_cut");
    std::fs::create_dir_all(&flip_dir).unwrap();
    for i in 0..bytes.len() {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x40;
        std::fs::write(flip_dir.join("runs.fcr"), &corrupt).unwrap();
        match RunStore::open(&flip_dir) {
            Ok(_) => panic!("flip at byte {i} went undetected"),
            Err(StoreError::Io(e)) => panic!("flip at byte {i}: io error {e}"),
            Err(_) => {} // any typed corruption error is correct
        }
    }
}

#[test]
fn oversized_and_foreign_files_are_rejected() {
    let dir = tmp("foreign");
    std::fs::create_dir_all(&dir).unwrap();
    // a foreign file
    std::fs::write(dir.join("runs.fcr"), b"GIF89a-not-a-store").unwrap();
    assert!(matches!(
        RunStore::open(&dir),
        Err(StoreError::BadMagic { .. })
    ));
    // valid header, absurd entry length
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"FCST");
    bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    bytes.extend_from_slice(b"FCRE");
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 64]);
    std::fs::write(dir.join("runs.fcr"), &bytes).unwrap();
    assert!(matches!(
        RunStore::open(&dir),
        Err(StoreError::Oversized { .. })
    ));
    // future format version
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"FCST");
    bytes.extend_from_slice(&99u32.to_le_bytes());
    std::fs::write(dir.join("runs.fcr"), &bytes).unwrap();
    assert!(matches!(
        RunStore::open(&dir),
        Err(StoreError::UnsupportedVersion { got: 99 })
    ));
}

#[test]
fn diff_reports_drift_fields_and_ignores_environment() {
    let a = rec("fedcompress", 7);
    // a faithful re-execution: same content, different wall/timestamp
    let mut b = rec("fedcompress", 7);
    b.created_unix = a.created_unix + 3600;
    for r in &mut b.rounds {
        r.wall_ms += 123.0;
    }
    assert!(diff_records(&a, &b).is_identical());

    let mut c = rec("fedcompress", 7);
    c.rounds[1].up_bytes += 1;
    c.final_accuracy += 1e-12;
    let d = diff_records(&a, &c);
    assert_eq!(d.fields.len(), 2);
    assert!(d.fields[0].contains("rounds[1]"), "{:?}", d.fields);
    assert!(d.fields[1].contains("final_accuracy"), "{:?}", d.fields);
}

#[test]
fn key_prefix_resolution_for_cli() {
    let dir = tmp("resolve");
    let mut store = RunStore::open(&dir).unwrap();
    let a = rec("fedavg", 8);
    store.append(&a).unwrap();
    let hex = key_hex(a.key);
    assert_eq!(store.resolve(&hex).unwrap(), a.key);
    assert_eq!(store.resolve(&hex[..8]).unwrap(), a.key);
    assert!(store.resolve("ffffffffffffffff").is_err() || a.key == u64::MAX);
}
