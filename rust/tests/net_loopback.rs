//! Loopback equivalence: `serve` + in-thread workers on `127.0.0.1:0`
//! must reproduce the in-process run bit-for-bit — `RoundMetrics`,
//! events, and the (framed) ledger — for every registered strategy.
//! Engine-gated like every other e2e suite (skips without built
//! artifacts). Also covers the real-fault surface (silent workers →
//! deadline cuts) and checkpoint resume mismatch warnings.

use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use fedcompress::baselines::registry::StrategyRegistry;
use fedcompress::compression::accounting::Direction;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::checkpoint::Checkpoint;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::coordinator::{run_with_strategy_opts, RunResult};
use fedcompress::net::proto::{Hello, Msg, Upload};
use fedcompress::net::{worker, InProcess, TcpServer, Transport, PROTO_VERSION};
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;

fn engine() -> Option<Engine> {
    let d = default_dir();
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load(&d).unwrap())
}

fn tiny_cfg(dataset: &str) -> FedConfig {
    let mut cfg = FedConfig::quick(dataset);
    cfg.rounds = 3;
    cfg.clients = 3;
    cfg.local_epochs = 2;
    cfg.server_epochs = 1;
    cfg.train_size = 192;
    cfg.test_size = 96;
    cfg.ood_size = 64;
    cfg.unlabeled_per_client = 16;
    cfg.warmup_rounds = 1;
    cfg.validate().unwrap();
    cfg
}

/// Run `strategy` over a real loopback socket with `n_workers`
/// in-thread worker runtimes (each loading its own engine).
fn loopback_run(cfg: &FedConfig, strategy: &str, n_workers: usize) -> RunResult {
    let engine = Engine::load(&default_dir()).unwrap();
    let data = build_data(&engine, cfg).unwrap();
    let server = TcpServer::bind("127.0.0.1:0", n_workers, cfg, strategy, None).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let handles: Vec<_> = (0..n_workers)
        .map(|_| {
            let addr = addr.clone();
            thread::spawn(move || worker::run_worker(&addr, &default_dir()))
        })
        .collect();

    let mut transport = server.accept_workers().unwrap();
    let mut plugin = StrategyRegistry::builtin().build(strategy, cfg).unwrap();
    let result = run_with_strategy_opts(
        &engine,
        cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        None,
    )
    .unwrap();
    transport.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    result
}

fn assert_equivalent(strategy: &str, inproc: &RunResult, loopback: &RunResult) {
    assert_eq!(inproc.final_theta, loopback.final_theta, "{strategy}: final model");
    assert_eq!(
        inproc.final_accuracy, loopback.final_accuracy,
        "{strategy}: final accuracy"
    );
    assert_eq!(
        inproc.final_model_bytes, loopback.final_model_bytes,
        "{strategy}: final wire size"
    );
    // RoundMetrics byte-identical (wall_ms is real time, everything
    // else must match bit-for-bit)
    assert_eq!(inproc.rounds.len(), loopback.rounds.len(), "{strategy}");
    for (a, b) in inproc.rounds.iter().zip(&loopback.rounds) {
        assert_eq!(a.round, b.round, "{strategy}");
        assert_eq!(a.accuracy, b.accuracy, "{strategy} round {}", a.round);
        assert_eq!(a.test_loss, b.test_loss, "{strategy} round {}", a.round);
        assert_eq!(a.score, b.score, "{strategy} round {}", a.round);
        assert_eq!(a.client_mean_ce, b.client_mean_ce, "{strategy} round {}", a.round);
        assert_eq!(a.clusters, b.clusters, "{strategy} round {}", a.round);
        assert_eq!(a.up_bytes, b.up_bytes, "{strategy} round {}", a.round);
        assert_eq!(a.down_bytes, b.down_bytes, "{strategy} round {}", a.round);
        assert_eq!(a.round_sim_ms, b.round_sim_ms, "{strategy} round {}", a.round);
        assert_eq!(a.stragglers, b.stragglers, "{strategy} round {}", a.round);
        assert_eq!(a.dropped, b.dropped, "{strategy} round {}", a.round);
    }
    // the structured event log agrees exactly
    assert_eq!(
        inproc.events.to_jsonl(),
        loopback.events.to_jsonl(),
        "{strategy}: event log diverged"
    );
    // the ledger agrees transfer-by-transfer, framed bytes included
    let (a, b) = (inproc.ledger.transfers(), loopback.ledger.transfers());
    assert_eq!(a.len(), b.len(), "{strategy}: transfer count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round, "{strategy}");
        assert_eq!(x.direction, y.direction, "{strategy}");
        assert_eq!(x.bytes, y.bytes, "{strategy}");
        assert_eq!(x.framed_bytes, y.framed_bytes, "{strategy}");
    }
}

// ---------------------------------------------------------------------------
// the headline guarantee
// ---------------------------------------------------------------------------

/// serve + 2 workers on loopback == in-process, for every registered
/// strategy, with `framed_bytes >= bytes` and overhead <= 64 B on
/// every ledger entry.
#[test]
fn loopback_equals_in_process_for_every_strategy() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();

    for strategy in StrategyRegistry::builtin().names() {
        let inproc = run_federated_with_data(&engine, &cfg, strategy, &data).unwrap();
        let loopback = loopback_run(&cfg, strategy, 2);
        assert_equivalent(strategy, &inproc, &loopback);

        // acceptance bound on the framed ledger
        assert!(loopback.ledger.transfer_count() > 0, "{strategy}");
        for t in loopback.ledger.transfers() {
            assert!(t.framed_bytes >= t.bytes, "{strategy}: framed < ideal");
            assert!(
                t.framed_bytes - t.bytes <= 64,
                "{strategy}: {} B overhead on a {:?} transfer",
                t.framed_bytes - t.bytes,
                t.direction
            );
        }
        assert!(loopback.total_framed_bytes() > loopback.total_bytes(), "{strategy}");
    }
}

/// The worker count is a deployment detail, not a semantic one: 1 and
/// 3 workers produce the same run as 2 (client ids, not sockets,
/// drive behavior).
#[test]
fn worker_count_does_not_change_the_run() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();
    let inproc = run_federated_with_data(&engine, &cfg, "fedcompress", &data).unwrap();
    for n_workers in [1, 3] {
        let loopback = loopback_run(&cfg, "fedcompress", n_workers);
        assert_equivalent("fedcompress", &inproc, &loopback);
    }
}

// ---------------------------------------------------------------------------
// real transport faults feed the existing fault machinery
// ---------------------------------------------------------------------------

/// A worker that handshakes and then never uploads is cut by the
/// per-client timeout: its clients surface as `Event::Deadline`, the
/// round completes with zero survivors, and the model never moves.
#[test]
fn silent_worker_is_cut_by_the_timeout() {
    let Some(engine) = engine() else { return };
    let mut cfg = tiny_cfg("cifar10");
    cfg.rounds = 2;
    let data = build_data(&engine, &cfg).unwrap();

    let server = TcpServer::bind(
        "127.0.0.1:0",
        1,
        &cfg,
        "fedavg",
        Some(Duration::from_millis(300)),
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    // a worker-shaped peer that accepts every download and never replies
    let h = thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        Msg::Hello(Hello {
            proto_version: PROTO_VERSION,
            edge_of: 0,
        })
        .write_to(&mut &stream)
        .unwrap();
        let Msg::HelloAck(_) = Msg::read_from(&mut &stream).unwrap() else {
            panic!("no ack")
        };
        // read whatever arrives until the coordinator hangs up
        while Msg::read_from(&mut &stream).is_ok() {}
    });

    let mut transport = server.accept_workers().unwrap();
    let mut plugin = StrategyRegistry::builtin().build("fedavg", &cfg).unwrap();
    let result = run_with_strategy_opts(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        None,
    )
    .unwrap();
    transport.shutdown().unwrap();
    assert_eq!(transport.alive_workers(), 0, "the silent worker was evicted");
    // closing the sockets unblocks the fake worker's read loop
    drop(transport);
    h.join().unwrap();

    // round 0: every client cut by the inactivity timeout
    // (Event::Deadline), which also evicts the connection, so round
    // 1's clients are transport dropouts (Event::Dropout)
    assert_eq!(result.events.of_kind("deadline").count(), cfg.clients);
    assert_eq!(result.events.of_kind("dropout").count(), cfg.clients);
    assert_eq!(result.ledger.bytes_in(Direction::Up), 0);
    for m in &result.rounds {
        assert_eq!(m.dropped, cfg.clients);
        assert_eq!(m.up_bytes, 0);
        // no survivors -> the evaluated model never changes
        assert_eq!(m.accuracy, result.rounds[0].accuracy);
    }
}

/// A hostile peer that handshakes correctly and then ships a ragged
/// upload (wrong parameter count) is evicted — its clients surface as
/// `Event::Dropout` — while the honest worker's round completes and
/// the run finishes with survivors every round. The coordinator never
/// panics and never aborts the run.
#[test]
fn ragged_upload_evicts_the_connection_and_the_round_survives() {
    let Some(engine) = engine() else { return };
    let mut cfg = tiny_cfg("cifar10");
    cfg.rounds = 2;
    // 4 clients over 2 workers: each connection owns exactly 2, so the
    // assertions hold whichever handshake order the threads win
    cfg.clients = 4;
    cfg.validate().unwrap();
    let data = build_data(&engine, &cfg).unwrap();

    let server = TcpServer::bind("127.0.0.1:0", 2, &cfg, "fedavg", None).unwrap();
    let addr = server.local_addr().unwrap();
    let addr_s = addr.to_string();
    // an honest worker for one connection...
    let honest = thread::spawn(move || worker::run_worker(&addr_s, &default_dir()));
    // ...and a protocol-correct but content-hostile peer for the other
    let hostile = thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        Msg::Hello(Hello {
            proto_version: PROTO_VERSION,
            edge_of: 0,
        })
        .write_to(&mut &stream)
        .unwrap();
        let Msg::HelloAck(_) = Msg::read_from(&mut &stream).unwrap() else {
            panic!("no ack")
        };
        let mut c_max = 0usize;
        loop {
            match Msg::read_from(&mut &stream) {
                Ok(Msg::RoundOpen(open)) => c_max = open.mu.len(),
                Ok(Msg::Download(d)) => {
                    // well-formed frame, well-formed message, ragged
                    // payload: 2 params where the model has thousands
                    let bad = Msg::Upload(Upload {
                        round: d.round,
                        client: d.client,
                        score: 0.5,
                        n: 7,
                        mean_ce: 0.1,
                        mu: vec![0.0; c_max],
                        stages: Vec::new(),
                        spec: "raw".into(),
                        payload: vec![0u8; 8],
                    });
                    if bad.write_to(&mut &stream).is_err() {
                        break;
                    }
                }
                Ok(_) => continue,
                Err(_) => break,
            }
        }
    });

    let mut transport = server.accept_workers().unwrap();
    let mut plugin = StrategyRegistry::builtin().build("fedavg", &cfg).unwrap();
    let result = run_with_strategy_opts(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        None,
    )
    .unwrap();
    assert_eq!(transport.alive_workers(), 1, "only the hostile peer was evicted");
    transport.shutdown().unwrap();
    drop(transport);
    honest.join().unwrap().unwrap();
    hostile.join().unwrap();

    // the hostile connection's 2 clients drop every round (evicted in
    // round 0, dead connection afterwards); the honest 2 survive
    assert_eq!(result.events.of_kind("dropout").count(), 2 * cfg.rounds);
    assert_eq!(result.events.of_kind("deadline").count(), 0);
    for m in &result.rounds {
        assert_eq!(m.dropped, 2, "round {}", m.round);
        assert!(m.up_bytes > 0, "round {} should have survivors", m.round);
    }
}

// ---------------------------------------------------------------------------
// checkpoint resume: environment stamping + mismatch warning
// ---------------------------------------------------------------------------

/// The resume contract, end to end: checkpointing a fedcompress run
/// after R rounds and resuming to R+2 must reproduce the uninterrupted
/// (R+2)-round run bit-for-bit — model, metrics, and controller
/// decisions (the score history is replayed into the plateau
/// controller via `FedStrategy::resume`).
#[test]
fn resume_is_bit_exact_continuation_for_fedcompress() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();

    let mut longer = cfg.clone();
    longer.rounds = cfg.rounds + 2;
    let uninterrupted = run_federated_with_data(&engine, &longer, "fedcompress", &data).unwrap();

    let first = run_federated_with_data(&engine, &cfg, "fedcompress", &data).unwrap();
    let scores: Vec<f64> = first.rounds.iter().map(|r| r.score).collect();
    let ckpt = Checkpoint::from_state(
        cfg.rounds,
        &first.final_theta,
        &first.final_centroids,
        &scores,
        "inproc",
        cfg.fleet.preset.name(),
    );
    let mut plugin = StrategyRegistry::builtin()
        .build("fedcompress", &longer)
        .unwrap();
    let mut transport = InProcess;
    let resumed = run_with_strategy_opts(
        &engine,
        &longer,
        plugin.as_mut(),
        &data,
        &mut transport,
        Some(&ckpt),
    )
    .unwrap();

    assert_eq!(resumed.final_theta, uninterrupted.final_theta);
    assert_eq!(resumed.final_accuracy, uninterrupted.final_accuracy);
    assert_eq!(resumed.final_model_bytes, uninterrupted.final_model_bytes);
    // the continuation rounds match the tail of the uninterrupted run,
    // cluster-controller decisions included
    let tail = &uninterrupted.rounds[cfg.rounds..];
    assert_eq!(resumed.rounds.len(), tail.len());
    for (a, b) in resumed.rounds.iter().zip(tail) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.accuracy, b.accuracy, "round {}", a.round);
        assert_eq!(a.score, b.score, "round {}", a.round);
        assert_eq!(a.clusters, b.clusters, "round {}", a.round);
        assert_eq!(a.up_bytes, b.up_bytes, "round {}", a.round);
        assert_eq!(a.down_bytes, b.down_bytes, "round {}", a.round);
    }
}

#[test]
fn resume_continues_and_mismatched_environment_warns() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();
    let first = run_federated_with_data(&engine, &cfg, "fedavg", &data).unwrap();
    let scores: Vec<f64> = first.rounds.iter().map(|r| r.score).collect();

    // continue the run for two more rounds from its checkpoint
    let ckpt = Checkpoint::from_state(
        cfg.rounds,
        &first.final_theta,
        &first.final_centroids,
        &scores,
        "inproc",
        cfg.fleet.preset.name(),
    );
    let mut longer = cfg.clone();
    longer.rounds = cfg.rounds + 2;
    let mut plugin = StrategyRegistry::builtin().build("fedavg", &longer).unwrap();
    let mut transport = InProcess;
    let resumed = run_with_strategy_opts(
        &engine,
        &longer,
        plugin.as_mut(),
        &data,
        &mut transport,
        Some(&ckpt),
    )
    .unwrap();
    // same environment: no warning, and only the new rounds ran
    assert_eq!(resumed.events.of_kind("resume_mismatch").count(), 0);
    assert_eq!(resumed.rounds.len(), 2);
    assert_eq!(resumed.rounds[0].round, cfg.rounds);

    // a checkpoint stamped with a different transport/fleet warns
    let foreign = Checkpoint {
        transport: "tcp".to_string(),
        fleet: "mobile".to_string(),
        ..ckpt.clone()
    };
    let mut plugin = StrategyRegistry::builtin().build("fedavg", &longer).unwrap();
    let warned = run_with_strategy_opts(
        &engine,
        &longer,
        plugin.as_mut(),
        &data,
        &mut transport,
        Some(&foreign),
    )
    .unwrap();
    let mismatches: Vec<_> = warned.events.of_kind("resume_mismatch").collect();
    assert_eq!(mismatches.len(), 1);
    let j = mismatches[0].to_json();
    assert_eq!(j.get("ckpt_transport").unwrap().as_str().unwrap(), "tcp");
    assert_eq!(j.get("run_transport").unwrap().as_str().unwrap(), "inproc");
    assert_eq!(j.get("ckpt_fleet").unwrap().as_str().unwrap(), "mobile");
    assert_eq!(j.get("run_fleet").unwrap().as_str().unwrap(), "ideal");
    // the warning does not change the training itself
    assert_eq!(warned.final_theta, resumed.final_theta);

    // resuming a finished run is a loud error, not a silent no-op
    let mut plugin = StrategyRegistry::builtin().build("fedavg", &cfg).unwrap();
    let err = run_with_strategy_opts(
        &engine,
        &cfg,
        plugin.as_mut(),
        &data,
        &mut transport,
        Some(&ckpt),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("already at round"), "{err}");
}
