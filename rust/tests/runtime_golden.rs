//! Runtime golden tests: execute every AOT artifact through the PJRT
//! runtime on the inputs `aot.py` recorded, and compare against the
//! outputs the *python* jitted functions produced. This is the
//! cross-language numerical contract — if it holds, the rust hot path
//! computes exactly what the L2/L1 stack defines.

use std::path::PathBuf;

use fedcompress::runtime::artifacts::{default_dir, DType};
use fedcompress::runtime::literals::{literal_to_f32, literal_to_i32, Arg};
use fedcompress::runtime::Engine;
use fedcompress::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let d = default_dir();
    d.join("manifest.json").exists().then_some(d)
}

enum Owned {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

fn load_goldens(engine: &Engine, dataset: &str) -> Vec<(String, Vec<Owned>, Vec<Owned>)> {
    let ds = engine.manifest.dataset(dataset).unwrap();
    let gdir = engine.manifest.dir.join(&ds.golden_dir);
    let text = std::fs::read_to_string(gdir.join("goldens.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    let mut cases = Vec::new();
    for (entry, rec) in j.as_obj().unwrap() {
        let read = |spec: &Json| -> Owned {
            let file = spec.get("file").unwrap().as_str().unwrap();
            let rel = format!("{}/{}", ds.golden_dir, file);
            match spec.get("dtype").unwrap().as_str().unwrap() {
                "i32" => Owned::I32(engine.manifest.read_i32_bin(&rel).unwrap()),
                _ => Owned::F32(engine.manifest.read_f32_bin(&rel).unwrap()),
            }
        };
        let in_arr = rec.get("inputs").unwrap().as_arr().unwrap();
        let ins: Vec<Owned> = in_arr.iter().map(read).collect();
        let out_arr = rec.get("outputs").unwrap().as_arr().unwrap();
        let outs: Vec<Owned> = out_arr.iter().map(read).collect();
        cases.push((entry.clone(), ins, outs));
    }
    cases
}

fn run_dataset_goldens(dataset: &str) {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::load(&dir).unwrap();
    let sig_owner = engine.manifest.dataset(dataset).unwrap().clone();

    for (entry, ins, outs) in load_goldens(&engine, dataset) {
        let sig = &sig_owner.signatures[&entry];
        let args: Vec<Arg<'_>> = ins
            .iter()
            .zip(&sig.inputs)
            .map(|(o, spec)| match (o, spec.dtype) {
                (Owned::F32(v), DType::F32) => {
                    if spec.shape.is_empty() {
                        Arg::Scalar(v[0])
                    } else {
                        Arg::F32(v)
                    }
                }
                (Owned::I32(v), DType::I32) => Arg::I32(v),
                _ => panic!("{dataset}.{entry}: golden dtype mismatch"),
            })
            .collect();

        let results = engine.run(dataset, &entry, &args).unwrap();
        assert_eq!(
            results.len(),
            outs.len(),
            "{dataset}.{entry}: output arity"
        );
        for (i, (got, want)) in results.iter().zip(&outs).enumerate() {
            match want {
                Owned::F32(w) => {
                    let g = literal_to_f32(got).unwrap();
                    assert_eq!(g.len(), w.len(), "{dataset}.{entry} out{i} len");
                    for (k, (a, b)) in g.iter().zip(w).enumerate() {
                        let tol = 1e-5f32 * (1.0 + b.abs());
                        assert!(
                            (a - b).abs() <= tol,
                            "{dataset}.{entry} out{i}[{k}]: {a} vs {b}"
                        );
                    }
                }
                Owned::I32(w) => {
                    let g = literal_to_i32(got).unwrap();
                    assert_eq!(&g, w, "{dataset}.{entry} out{i}");
                }
            }
        }
    }
}

#[test]
fn goldens_cifar10() {
    run_dataset_goldens("cifar10");
}

#[test]
fn goldens_cifar100() {
    run_dataset_goldens("cifar100");
}

#[test]
fn goldens_pathmnist() {
    run_dataset_goldens("pathmnist");
}

#[test]
fn goldens_speechcommands() {
    run_dataset_goldens("speechcommands");
}

#[test]
fn goldens_voxforge() {
    run_dataset_goldens("voxforge");
}

/// The rust codec's snap and the HLO snap kernel agree exactly.
#[test]
fn rust_snap_matches_hlo_snap() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let engine = Engine::load(&dir).unwrap();
    let dataset = "cifar10";
    let theta = engine.init_theta(dataset).unwrap();
    let c_max = engine.manifest.c_max;

    // active sorted codebook + sentinel padding, exactly like the runtime
    let mut rng = fedcompress::util::rng::Rng::new(3);
    let cents = fedcompress::clustering::CentroidState::init_from_weights(
        &theta, 16, c_max, &mut rng,
    );
    let out = engine
        .run(
            dataset,
            "snap",
            &[
                Arg::F32(&theta),
                Arg::F32(&cents.mu),
                Arg::F32(&cents.mask),
            ],
        )
        .unwrap();
    let hlo_snapped = literal_to_f32(&out[0]).unwrap();

    let codebook = cents.active_codebook();
    let mut rust_snapped = theta.clone();
    fedcompress::compression::kmeans::snap(&mut rust_snapped, &codebook);

    let mut mismatches = 0;
    for (a, b) in hlo_snapped.iter().zip(&rust_snapped) {
        // boundary ties may fall either way; values must still be close
        if a != b {
            mismatches += 1;
            assert!((a - b).abs() < 0.25, "snap diverges beyond one centroid");
        }
    }
    assert!(
        (mismatches as f64) < 0.001 * theta.len() as f64,
        "{mismatches} snap mismatches"
    );
}
