//! Seeded property tests over the wire layer: for every strategy in
//! `StrategyRegistry::builtin()`, random theta sizes/values must
//! round-trip through `encode_upload` / `encode_download` with the
//! decode invariant (`ensure_param_count`) holding, decoded values
//! finite, and wire bytes never above dense — strictly below it for the
//! compressing strategies. No external property-test crates: cases are
//! driven by the repo's own deterministic `Rng`.

use fedcompress::baselines::registry::StrategyRegistry;
use fedcompress::clustering::CentroidState;
use fedcompress::compression::codec::dense_bytes;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::strategy::{RoundContext, ServerModel, UploadInput};
use fedcompress::util::rng::Rng;

/// Strategies whose *upload* is compressed once compression engages.
/// (Strategies outside this list must still never exceed dense.)
const COMPRESSING_UPLOADS: [&str; 3] = ["fedzip", "fedcompress", "topk"];

/// Strategies whose *download* is compressed once SCS has run.
const COMPRESSING_DOWNLOADS: [&str; 1] = ["fedcompress"];

fn ctx_at<'a>(round: usize, cfg: &'a FedConfig, base: &'a Rng) -> RoundContext<'a> {
    RoundContext {
        round,
        cfg,
        base,
        compressing: round >= cfg.warmup_rounds,
        down_compressed: round > cfg.warmup_rounds,
    }
}

/// Random model state: theta from a scaled normal (occasionally with
/// heavy outliers, the k-means stressor) plus an initialized codebook.
fn random_state(n: usize, rng: &mut Rng) -> (Vec<f32>, CentroidState) {
    let scale = 0.05 + rng.f32() * 0.5;
    let heavy_tail = rng.f32() < 0.3;
    let theta: Vec<f32> = (0..n)
        .map(|_| {
            let w = rng.normal() * scale;
            if heavy_tail && rng.f32() < 0.01 {
                w * 50.0
            } else {
                w
            }
        })
        .collect();
    let cents = CentroidState::init_from_weights(&theta, 16, 32, rng);
    (theta, cents)
}

#[test]
fn every_strategy_upload_round_trips_at_random_sizes() {
    let cfg = FedConfig::quick("cifar10");
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let reg = StrategyRegistry::builtin();

    for name in reg.names() {
        let strategy = reg.build(name, &cfg).unwrap();
        let mut case_rng = Rng::new(0xC0FFEE ^ name.len() as u64);
        for case in 0..12 {
            // random size in [256, 8447]; both warmup and late rounds
            let n = 256 + case_rng.below(8192);
            let (theta, cents) = random_state(n, &mut case_rng);
            let dense = dense_bytes(n);
            for round in [0, cfg.warmup_rounds + 2] {
                let ctx = ctx_at(round, &cfg, &base);
                let mut enc_rng = base.fork(7_000 + case as u64);
                let blob = strategy
                    .encode_upload(
                        &ctx,
                        &UploadInput {
                            client: case,
                            theta: &theta,
                            centroids: &cents,
                        },
                        &mut enc_rng,
                    )
                    .unwrap();
                // decode invariant: the receiver reconstructs exactly n
                // params, all finite
                assert!(
                    blob.ensure_param_count(n).is_ok(),
                    "{name} n={n} round={round}: decoded {} params",
                    blob.theta.len()
                );
                assert!(
                    blob.theta.iter().all(|w| w.is_finite()),
                    "{name} n={n} round={round}: non-finite decode"
                );
                // byte bound: never above dense...
                assert!(
                    blob.bytes <= dense,
                    "{name} n={n} round={round}: {} > dense {dense}",
                    blob.bytes
                );
                // ...and strictly below it for compressing strategies
                // once compression engages
                if ctx.compressing && COMPRESSING_UPLOADS.contains(&name) {
                    assert!(
                        blob.bytes < dense,
                        "{name} n={n} round={round}: not compressed ({} vs {dense})",
                        blob.bytes
                    );
                }
            }
        }
    }
}

#[test]
fn every_strategy_download_round_trips() {
    let cfg = FedConfig::quick("cifar10");
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let reg = StrategyRegistry::builtin();

    for name in reg.names() {
        let strategy = reg.build(name, &cfg).unwrap();
        let mut case_rng = Rng::new(0xD00D ^ name.len() as u64);
        for _case in 0..8 {
            let n = 256 + case_rng.below(4096);
            let (theta, centroids) = random_state(n, &mut case_rng);
            let model = ServerModel { theta, centroids };
            let dense = dense_bytes(n);
            for round in [0, cfg.warmup_rounds + 2] {
                let ctx = ctx_at(round, &cfg, &base);
                let blob = strategy.encode_download(&ctx, &model).unwrap();
                assert!(
                    blob.ensure_param_count(n).is_ok(),
                    "{name} n={n} round={round}: decoded {} params",
                    blob.theta.len()
                );
                assert!(blob.theta.iter().all(|w| w.is_finite()));
                assert!(blob.bytes <= dense, "{name}: {} > {dense}", blob.bytes);
                if ctx.down_compressed && COMPRESSING_DOWNLOADS.contains(&name) {
                    assert!(blob.bytes < dense, "{name} n={n}: downstream not compressed");
                }
            }
        }
    }
}

#[test]
fn upload_encode_is_deterministic_given_the_rng_fork() {
    // the serial==parallel guarantee rests on this: same input + same
    // RNG position => bit-identical blob
    let cfg = FedConfig::quick("cifar10");
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let reg = StrategyRegistry::builtin();
    let ctx = ctx_at(cfg.warmup_rounds + 1, &cfg, &base);

    for name in reg.names() {
        let strategy = reg.build(name, &cfg).unwrap();
        let mut rng = Rng::new(99);
        let (theta, cents) = random_state(2048, &mut rng);
        let input = UploadInput {
            client: 0,
            theta: &theta,
            centroids: &cents,
        };
        let mut r1 = base.fork(42);
        let mut r2 = base.fork(42);
        let a = strategy.encode_upload(&ctx, &input, &mut r1).unwrap();
        let b = strategy.encode_upload(&ctx, &input, &mut r2).unwrap();
        assert_eq!(a.bytes, b.bytes, "{name}");
        assert_eq!(a.theta, b.theta, "{name}");
    }
}
