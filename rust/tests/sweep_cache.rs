//! Sweep orchestrator tests: resume-by-cache (re-running an identical
//! sweep executes nothing), zero drift between independently swept
//! stores, spec-file expansion, and the bench export — all on the
//! engine-free `SmokeRunner`. The engine-gated case at the bottom
//! proves the real `exp::fleet` table is deterministic through the
//! store cache (identical rows, second pass all cache hits).

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use fedcompress::baselines::registry::StrategyRegistry;
use fedcompress::config::FedConfig;
use fedcompress::store::{diff_records, export, RunStore};
use fedcompress::sweep::{run_sweep, SmokeRunner, SweepEvent, SweepOutcome, SweepSpec};
use fedcompress::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("fedcompress_sweep_cache")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quiet(_: SweepEvent) {}

fn grid() -> (FedConfig, SweepSpec) {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = 4;
    let spec = SweepSpec {
        strategies: vec!["fedavg".into(), "fedcompress".into(), "topk".into()],
        seeds: vec![41, 42],
        ..SweepSpec::default()
    };
    (cfg, spec)
}

fn sweep_into(dir: &Path) -> (RunStore, SweepOutcome) {
    let (cfg, spec) = grid();
    let jobs = spec.expand(&cfg, &StrategyRegistry::builtin()).unwrap();
    let mut store = RunStore::open(dir).unwrap();
    let out = run_sweep(&jobs, &mut store, &SmokeRunner, 4, false, None, &quiet).unwrap();
    (store, out)
}

/// The acceptance criterion: re-running an identical sweep hits the
/// record cache for every job (zero re-execution), and `runs diff`
/// between the two store states reports zero drift on every key.
#[test]
fn identical_sweeps_cache_fully_and_never_drift() {
    let dir_a = tmp("drift_a");
    let dir_b = tmp("drift_b");
    let (mut store_a, first) = sweep_into(&dir_a);
    assert_eq!(first.executed, 6);
    assert_eq!(first.cached, 0);
    assert_eq!(first.failed, 0);

    // same sweep, same store: zero re-execution
    let (cfg, spec) = grid();
    let jobs = spec.expand(&cfg, &StrategyRegistry::builtin()).unwrap();
    let second = run_sweep(&jobs, &mut store_a, &SmokeRunner, 4, false, None, &quiet).unwrap();
    assert_eq!(second.executed, 0, "cache must absorb every job");
    assert_eq!(second.cached, 6);

    // same sweep, independent store: every shared key bit-identical
    let (store_b, _) = sweep_into(&dir_b);
    assert_eq!(store_a.keys(), store_b.keys());
    for key in store_a.keys() {
        let a = store_a.get(key).unwrap().unwrap();
        let b = store_b.get(key).unwrap().unwrap();
        let d = diff_records(&a, &b);
        assert!(d.is_identical(), "key {key:016x} drifted: {:?}", d.fields);
    }
}

#[test]
fn progress_stream_reports_cache_hits() {
    let dir = tmp("progress");
    let (mut store, _) = sweep_into(&dir);
    let (cfg, spec) = grid();
    let jobs = spec.expand(&cfg, &StrategyRegistry::builtin()).unwrap();
    let cached_seen = Mutex::new(0usize);
    run_sweep(&jobs, &mut store, &SmokeRunner, 2, false, None, &|e| {
        if let SweepEvent::JobDone { cached: true, .. } = e {
            *cached_seen.lock().unwrap() += 1;
        }
    })
    .unwrap();
    assert_eq!(*cached_seen.lock().unwrap(), jobs.len());
}

#[test]
fn spec_file_drives_the_same_pipeline() {
    let dir = tmp("specfile");
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("grid.sweep");
    std::fs::write(
        &spec_path,
        "# smoke grid\nstrategies = fedavg,fedzip\nseeds = 1,2\ngrid.c_max = 16,32\n",
    )
    .unwrap();
    let spec = SweepSpec::from_file(&spec_path).unwrap();
    let cfg = FedConfig::quick("cifar10");
    let jobs = spec.expand(&cfg, &StrategyRegistry::builtin()).unwrap();
    assert_eq!(jobs.len(), 2 * 2 * 2);
    let mut store = RunStore::open(&dir.join("store")).unwrap();
    let out = run_sweep(&jobs, &mut store, &SmokeRunner, 4, false, None, &quiet).unwrap();
    assert_eq!(out.executed, 8);
    // the swept axis really landed in the stored configs
    let mut c_maxes: Vec<usize> = store
        .keys()
        .into_iter()
        .map(|k| store.get(k).unwrap().unwrap().cfg().unwrap().controller.c_max)
        .collect();
    c_maxes.sort_unstable();
    c_maxes.dedup();
    assert_eq!(c_maxes, vec![16, 32]);
}

#[test]
fn export_bench_summarizes_the_sweep() {
    let dir = tmp("bench");
    let (store, _) = sweep_into(&dir);
    let out = dir.join("BENCH_sweep.json");
    export::write_bench_json(&store, &out).unwrap();
    let doc = Json::parse(std::fs::read_to_string(&out).unwrap().trim()).unwrap();
    assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "sweep");
    assert_eq!(doc.get("records").unwrap().as_usize().unwrap(), 6);
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 6);
    for r in runs {
        assert!(r.get("final_accuracy").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("total_bytes").unwrap().as_usize().unwrap() > 0);
    }
    let by = doc.get("by_strategy").unwrap();
    for name in ["fedavg", "fedcompress", "topk"] {
        let g = by.get(name).unwrap();
        assert_eq!(g.get("runs").unwrap().as_usize().unwrap(), 2, "{name}");
    }
}

// ---------------------------------------------------------------------------
// engine-gated: the real fleet table through the store cache
// ---------------------------------------------------------------------------

fn engine() -> Option<fedcompress::runtime::Engine> {
    let d = fedcompress::runtime::artifacts::default_dir();
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(fedcompress::runtime::Engine::load(&d).unwrap())
}

/// Satellite guarantee: the same seed + preset produce identical
/// `FleetRow`s twice, with the second pass served entirely from the
/// run store (cache-hit asserted, zero re-execution).
#[test]
fn fleet_table_is_deterministic_through_store_cache() {
    let Some(engine) = engine() else { return };
    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = 3;
    cfg.clients = 3;
    cfg.local_epochs = 2;
    cfg.server_epochs = 1;
    cfg.train_size = 192;
    cfg.test_size = 96;
    cfg.ood_size = 64;
    cfg.unlabeled_per_client = 16;
    cfg.warmup_rounds = 1;
    cfg.validate().unwrap();

    let dir = tmp("fleet_cache");
    let mut store = RunStore::open(&dir).unwrap();
    let presets = [fedcompress::sim::FleetPreset::Ideal];
    let n_strategies = StrategyRegistry::builtin().names().len();

    let (first, stats) =
        fedcompress::exp::fleet::run_cached(&engine, &cfg, &presets, Some(&mut store)).unwrap();
    assert_eq!(stats.hits, 0);
    assert_eq!(stats.misses, n_strategies);
    assert_eq!(store.len(), n_strategies);

    let (second, stats) =
        fedcompress::exp::fleet::run_cached(&engine, &cfg, &presets, Some(&mut store)).unwrap();
    assert_eq!(stats.misses, 0, "second pass must not re-execute");
    assert_eq!(stats.hits, n_strategies);
    assert_eq!(first, second, "identical FleetRows through the cache");
    assert_eq!(store.len(), n_strategies, "no new records");
}
