//! Mux-layer behaviour over real localhost sockets: the readiness
//! loop round-trips frames across many connections, surfaces a dead
//! peer as `MuxEvent::Closed` without touching its neighbours, and
//! the accept path's handshake timeout drops a silent connector
//! instead of wedging `accept_workers` forever.

use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use fedcompress::config::FedConfig;
use fedcompress::net::frame::encode_frame;
use fedcompress::net::proto::Hello;
use fedcompress::net::{
    read_frame, write_frame, Msg, Mux, MuxEvent, ProtoError, TcpServer, Transport, PROTO_VERSION,
};

/// Drive the mux until `done` says so, sleeping briefly on idle
/// passes. Panics (instead of hanging CI) if the condition never
/// lands.
fn poll_until(
    mux: &mut Mux,
    events: &mut Vec<MuxEvent>,
    mut done: impl FnMut(&Mux, &[MuxEvent]) -> bool,
) {
    for _ in 0..20_000 {
        if done(mux, events) {
            return;
        }
        if !mux.poll(events) {
            thread::sleep(Duration::from_micros(200));
        }
    }
    panic!("mux poll loop did not converge");
}

/// Frames written by independent peers come out of `poll` attributed
/// to the right connection, and enqueued replies drain back out —
/// the full readiness-loop round trip, no protocol layer involved.
#[test]
fn mux_round_trips_frames_across_connections() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let peer = |ty: u8, body: Vec<u8>| {
        thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            write_frame(&mut &stream, ty, &body).unwrap();
            // read the echo (type bumped by one)
            let (echo_ty, echo) = read_frame(&mut &stream).unwrap();
            assert_eq!(echo_ty, ty + 1);
            assert_eq!(echo, body);
        })
    };
    let h1 = peer(10, vec![0xAB; 5_000]);
    let h2 = peer(20, (0..255u8).collect());

    let s1 = listener.accept().unwrap().0;
    let s2 = listener.accept().unwrap().0;
    let mut mux = Mux::new(vec![s1, s2]).unwrap();
    assert_eq!(mux.len(), 2);

    let mut events = Vec::new();
    poll_until(&mut mux, &mut events, |_, ev| {
        ev.iter()
            .filter(|e| matches!(e, MuxEvent::Frame { .. }))
            .count()
            >= 2
    });
    for ev in &events {
        match ev {
            MuxEvent::Frame { conn, msg_type, payload } => {
                // echo back with the type bumped, on the same conn
                let reply = encode_frame(msg_type + 1, payload);
                mux.enqueue(*conn, &reply);
            }
            MuxEvent::Closed { conn, error } => panic!("conn {conn} closed: {error}"),
        }
    }
    let mut drained = Vec::new();
    poll_until(&mut mux, &mut drained, |m, _| {
        m.outbox_len(0) == 0 && m.outbox_len(1) == 0
    });
    h1.join().unwrap();
    h2.join().unwrap();
}

/// A peer hanging up surfaces as exactly one `Closed` on its own
/// connection; the surviving connection keeps exchanging frames.
#[test]
fn dead_peer_closes_its_connection_only() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let dier = thread::spawn(move || {
        drop(TcpStream::connect(addr).unwrap());
    });
    let survivor = thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        write_frame(&mut &stream, 7, b"still here").unwrap();
        // stay connected until the mux hangs up, so only the dier's
        // connection ever closes while the assertions run
        let mut sink = Vec::new();
        let _ = std::io::Read::read_to_end(&mut stream, &mut sink);
    });
    let s1 = listener.accept().unwrap().0;
    let s2 = listener.accept().unwrap().0;
    let mut mux = Mux::new(vec![s1, s2]).unwrap();

    let mut events = Vec::new();
    poll_until(&mut mux, &mut events, |_, ev| {
        let closed = ev.iter().any(|e| matches!(e, MuxEvent::Closed { .. }));
        let framed = ev
            .iter()
            .any(|e| matches!(e, MuxEvent::Frame { payload, .. } if payload == b"still here"));
        closed && framed
    });
    let closed: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            MuxEvent::Closed { conn, error } => {
                assert!(
                    matches!(error, ProtoError::Truncated { .. } | ProtoError::Io(_)),
                    "{error}"
                );
                Some(*conn)
            }
            _ => None,
        })
        .collect();
    assert_eq!(closed.len(), 1, "exactly one connection died");
    assert!(!mux.is_open(closed[0]));
    assert!(mux.is_open(1 - closed[0]), "the survivor stays open");
    mux.close(1 - closed[0]); // release the survivor
    dier.join().unwrap();
    survivor.join().unwrap();
}

/// A connector that never speaks cannot wedge `accept_workers`: the
/// handshake timeout (config `handshake_timeout_s`, surfaced as
/// `--handshake-timeout-s`) drops it and the listener keeps accepting
/// until a real worker completes the grant.
#[test]
fn silent_connector_is_dropped_after_the_handshake_timeout() {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.set("handshake_timeout_s", "0.3").unwrap();
    let server = TcpServer::bind("127.0.0.1:0", 1, &cfg, "fedavg", None).unwrap();
    let addr = server.local_addr().unwrap();

    // connects, says nothing, waits to be hung up on
    let silent = thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut sink = Vec::new();
        let _ = std::io::Read::read_to_end(&mut stream, &mut sink);
        assert!(sink.is_empty(), "a silent peer earns no grant");
    });
    thread::sleep(Duration::from_millis(100)); // pin arrival order
    let real = thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        Msg::Hello(Hello {
            proto_version: PROTO_VERSION,
            edge_of: 0,
        })
        .write_to(&mut &stream)
        .unwrap();
        let ack = match Msg::read_from(&mut &stream).unwrap() {
            Msg::HelloAck(a) => a,
            other => panic!("expected HelloAck, got {}", other.kind()),
        };
        assert_eq!(ack.worker, 0);
        assert_eq!(ack.workers, 1);
        match Msg::read_from(&mut &stream).unwrap() {
            Msg::Shutdown => {}
            other => panic!("expected Shutdown, got {}", other.kind()),
        }
    });

    let mut transport = server.accept_workers().unwrap();
    assert_eq!(transport.alive_workers(), 1);
    transport.shutdown().unwrap();
    silent.join().unwrap();
    real.join().unwrap();
}
