//! Backend equivalence: every available kernel backend must be
//! **bit-identical** to the scalar reference on every input — wire
//! bytes and run keys are content-addressed, so a single diverging
//! lane would fork the whole experiment record space.
//!
//! Sizes sweep 0..=17 plus 64+r for r in 0..8 and a few larger ones,
//! so every vector width in use (8-lane f32, 4-lane f64, 2-lane
//! converts) sees every possible remainder tail. Inputs are seeded
//! random floats salted with the unfriendly cases: NaN, infinities,
//! signed zeros, and denormals.

use fedcompress::kernels::{
    abs_max_on, assign_nearest_on, available_backends, axpy_f64_on, histogram_u32_on,
    magnitude_keys_on, pack_bits_on, snap_to_codebook_on, threshold_count_on, unpack_bits_on,
    Backend,
};
use fedcompress::util::rng::Rng;

/// Every size in 0..=17 (all 8-lane and 4-lane tails at small n),
/// every remainder class around 64, and a few larger payloads.
fn sizes() -> Vec<usize> {
    let mut v: Vec<usize> = (0..=17).collect();
    v.extend((0..8).map(|r| 64 + r));
    v.extend([255, 1000, 4096, 4097]);
    v
}

/// Random weights with the special values sprinkled deterministically.
fn weights(rng: &mut Rng, n: usize, specials: bool) -> Vec<f32> {
    let mut xs: Vec<f32> = (0..n).map(|_| rng.normal() * 2.0).collect();
    if specials {
        let table = [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.0,
            -0.0,
            f32::MIN_POSITIVE / 2.0, // denormal
            -1.0e-42,
            f32::MAX,
        ];
        for (i, x) in xs.iter_mut().enumerate() {
            if i % 7 == 3 {
                *x = table[i % table.len()];
            }
        }
    }
    xs
}

fn simd_backends() -> Vec<Backend> {
    available_backends()
        .into_iter()
        .filter(|&b| b != Backend::Scalar)
        .collect()
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn magnitude_keys_match_scalar_on_every_tail() {
    let mut rng = Rng::new(21);
    for n in sizes() {
        let xs = weights(&mut rng, n, true);
        let mut want = vec![0u32; n];
        magnitude_keys_on(Backend::Scalar, &xs, &mut want);
        for b in simd_backends() {
            let mut got = vec![0u32; n];
            magnitude_keys_on(b, &xs, &mut got);
            assert_eq!(got, want, "{b:?} n={n}");
        }
    }
}

#[test]
fn abs_max_matches_scalar_bit_for_bit() {
    let mut rng = Rng::new(22);
    for n in sizes() {
        for specials in [false, true] {
            let xs = weights(&mut rng, n, specials);
            let want = abs_max_on(Backend::Scalar, &xs);
            for b in simd_backends() {
                let got = abs_max_on(b, &xs);
                assert_eq!(got.to_bits(), want.to_bits(), "{b:?} n={n} specials={specials}");
            }
        }
    }
}

#[test]
fn threshold_count_matches_scalar_at_every_threshold_class() {
    let mut rng = Rng::new(23);
    for n in sizes() {
        let xs = weights(&mut rng, n, true);
        let mut keys = vec![0u32; n];
        magnitude_keys_on(Backend::Scalar, &xs, &mut keys);
        let mut thresholds = vec![0u32, 0x7FFF_FFFF];
        if n > 0 {
            thresholds.push(keys[n / 2]);
            thresholds.push(keys[0]);
        }
        for t in thresholds {
            let want = threshold_count_on(Backend::Scalar, &keys, t);
            for b in simd_backends() {
                assert_eq!(threshold_count_on(b, &keys, t), want, "{b:?} n={n} t={t:#x}");
            }
        }
    }
}

#[test]
fn assign_nearest_matches_the_binary_search_everywhere() {
    let mut rng = Rng::new(24);
    // codebook sizes: 1 (degenerate), paper range, the >64+1 scalar-
    // delegation threshold on both sides, and equal-centroid ties
    for c in [1usize, 2, 3, 15, 16, 64, 65, 66, 100] {
        let mut cb: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
        cb.sort_by(f32::total_cmp);
        for n in sizes() {
            let xs = weights(&mut rng, n, true);
            let mut want = vec![0u32; n];
            assign_nearest_on(Backend::Scalar, &xs, &cb, &mut want);
            for b in simd_backends() {
                let mut got = vec![0u32; n];
                assign_nearest_on(b, &xs, &cb, &mut got);
                assert_eq!(got, want, "{b:?} c={c} n={n}");
            }
        }
    }
    // repeated centroids: boundary ties must break identically
    let cb = [-1.0f32, 0.0, 0.0, 0.0, 2.0];
    let xs = weights(&mut rng, 129, true);
    let mut want = vec![0u32; xs.len()];
    assign_nearest_on(Backend::Scalar, &xs, &cb, &mut want);
    for b in simd_backends() {
        let mut got = vec![0u32; xs.len()];
        assign_nearest_on(b, &xs, &cb, &mut got);
        assert_eq!(got, want, "{b:?} tied codebook");
    }
}

#[test]
fn snap_matches_scalar_indices_and_weights() {
    let mut rng = Rng::new(25);
    let mut cb: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    cb.sort_by(f32::total_cmp);
    for n in sizes() {
        let xs = weights(&mut rng, n, true);
        let mut want_w = xs.clone();
        let want_idx = snap_to_codebook_on(Backend::Scalar, &mut want_w, &cb);
        for b in simd_backends() {
            let mut got_w = xs.clone();
            let got_idx = snap_to_codebook_on(b, &mut got_w, &cb);
            assert_eq!(got_idx, want_idx, "{b:?} n={n}");
            assert_eq!(bits_of(&got_w), bits_of(&want_w), "{b:?} n={n}");
        }
    }
}

#[test]
fn histogram_matches_scalar_counts() {
    let mut rng = Rng::new(26);
    for n in sizes() {
        for alphabet in [1usize, 2, 17, 256] {
            let symbols: Vec<u32> = (0..n).map(|_| rng.below(alphabet) as u32).collect();
            let want = histogram_u32_on(Backend::Scalar, &symbols, alphabet);
            for b in simd_backends() {
                assert_eq!(
                    histogram_u32_on(b, &symbols, alphabet),
                    want,
                    "{b:?} n={n} alphabet={alphabet}"
                );
            }
        }
    }
}

#[test]
fn pack_bits_bytes_match_scalar_for_every_width() {
    let mut rng = Rng::new(27);
    for n in sizes() {
        for bits in [1u32, 2, 3, 7, 8, 9, 11, 13, 16, 17, 24, 31, 32] {
            let values: Vec<u32> = (0..n)
                .map(|_| {
                    let v = rng.next_u64() as u32;
                    if bits == 32 {
                        v
                    } else {
                        v & ((1u32 << bits) - 1)
                    }
                })
                .collect();
            let want = pack_bits_on(Backend::Scalar, &values, bits);
            for b in simd_backends() {
                assert_eq!(pack_bits_on(b, &values, bits), want, "{b:?} n={n} bits={bits}");
            }
        }
    }
}

#[test]
fn unpack_bits_matches_scalar_including_truncation_verdicts() {
    let mut rng = Rng::new(28);
    for n in sizes() {
        for bits in [1u32, 3, 8, 11, 16, 31, 32] {
            let values: Vec<u32> = (0..n)
                .map(|_| {
                    let v = rng.next_u64() as u32;
                    if bits == 32 {
                        v
                    } else {
                        v & ((1u32 << bits) - 1)
                    }
                })
                .collect();
            let bytes = pack_bits_on(Backend::Scalar, &values, bits);
            // exact, truncated-by-one, padded-by-one: all must agree
            let mut padded = bytes.clone();
            padded.push(0xAB);
            let mut cases: Vec<&[u8]> = vec![&bytes, &padded];
            if !bytes.is_empty() {
                cases.push(&bytes[..bytes.len() - 1]);
            }
            for case in cases {
                let want = unpack_bits_on(Backend::Scalar, case, bits, n);
                for b in simd_backends() {
                    assert_eq!(unpack_bits_on(b, case, bits, n), want, "{b:?} n={n} bits={bits}");
                }
                if case.len() >= bytes.len() {
                    assert_eq!(want.as_deref(), Some(values.as_slice()));
                }
            }
        }
    }
}

#[test]
fn axpy_reproduces_the_scalar_rounding_sequence() {
    let mut rng = Rng::new(29);
    for n in sizes() {
        for specials in [false, true] {
            let xs = weights(&mut rng, n, specials);
            let init: Vec<f64> = (0..n).map(|_| f64::from(rng.normal())).collect();
            for w in [0.0f64, 1.0, 0.1234567, -3.75, 1e-300] {
                let mut want = init.clone();
                axpy_f64_on(Backend::Scalar, &mut want, &xs, w);
                let want_bits: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
                for b in simd_backends() {
                    let mut got = init.clone();
                    axpy_f64_on(b, &mut got, &xs, w);
                    let got_bits: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got_bits, want_bits, "{b:?} n={n} w={w}");
                }
            }
        }
    }
}

#[test]
fn explicitly_requested_scalar_env_value_is_honored() {
    // `active()` latches on first use, so we only assert the latched
    // value is a backend this machine can actually run — the CI matrix
    // forces FEDCOMPRESS_KERNELS=scalar for a full-suite pass.
    assert!(fedcompress::kernels::active().available());
}
