//! Integration tests for the perf-trajectory surface: the versioned
//! `BENCH_*.json` schema round-trips through real files, the
//! `bench diff` gate handles its edge cases (threshold boundary,
//! degenerate medians, missing/added rows, malformed baselines), a
//! quick headless area run self-diffs clean, and fedlint's
//! `no-wallclock-state` rule holds over `src/` with `util::timer` as
//! the only sanctioned allow site.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use fedcompress::bench::diff::{diff_docs, RowStatus, DEFAULT_THRESHOLD_PCT};
use fedcompress::bench::schema::{BenchDoc, BenchError, BenchRow, BENCH_FORMAT};
use fedcompress::bench::suite::run_area;
use fedcompress::lint::config::LintConfig;
use fedcompress::lint::lint_root;
use fedcompress::util::json::Json;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedcompress_bench_it_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn doc_with(rows: Vec<(&str, &str, f64)>) -> BenchDoc {
    let mut doc = BenchDoc::new("codec", true);
    for (suite, name, median_ns) in rows {
        doc.rows.push(BenchRow {
            suite: suite.to_string(),
            name: name.to_string(),
            median_ns,
            p10_ns: median_ns * 0.9,
            p90_ns: median_ns * 1.2,
            iters: 10,
            bytes: None,
        });
    }
    doc
}

#[test]
fn documents_round_trip_through_files_with_extra_keys() {
    let dir = scratch("roundtrip");
    let mut doc = doc_with(vec![("pipelines", "pipe_encode[dense]", 81_234.0)]);
    doc.rows[0].bytes = Some(78_696);
    doc.extra
        .insert("records".to_string(), Json::from(6usize));
    doc.extra.insert(
        "by_strategy".to_string(),
        Json::obj(vec![("fedavg", Json::from(3usize))]),
    );

    let path = dir.join("nested/BENCH_codec.json");
    doc.write(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.ends_with('\n'), "writer emits a trailing newline");

    let back = BenchDoc::load(&path).unwrap();
    assert_eq!(back, doc);
    assert_eq!(back.format, BENCH_FORMAT);
    assert_eq!(back.extra.len(), 2, "producer keys survive the trip");
    // derived throughput is recomputed from bytes/median, never stored
    // as truth: byte-carrying rows expose it, bare rows do not
    assert!(back.rows[0].mib_s().unwrap() > 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn diff_gate_edges_through_the_file_api() {
    let dir = scratch("diffedges");
    // zero is the degenerate median that can survive a JSON trip (the
    // writer has no NaN literal); NaN is covered in-memory below
    let old = doc_with(vec![
        ("s", "boundary", 100.0),
        ("s", "zero", 0.0),
        ("s", "gone", 100.0),
    ]);
    let new = doc_with(vec![
        ("s", "boundary", 125.0),
        ("s", "zero", 90.0),
        ("s", "fresh", 50.0),
    ]);
    let (op, np) = (dir.join("old.json"), dir.join("new.json"));
    old.write(&op).unwrap();
    new.write(&np).unwrap();
    let (old, new) = (BenchDoc::load(&op).unwrap(), BenchDoc::load(&np).unwrap());

    let d = diff_docs(&old, &new, DEFAULT_THRESHOLD_PCT);
    let by_id: BTreeMap<&str, RowStatus> =
        d.rows.iter().map(|r| (r.id.as_str(), r.status)).collect();
    assert_eq!(by_id["s/boundary"], RowStatus::Ok, "exact threshold passes");
    assert_eq!(by_id["s/zero"], RowStatus::Incomparable);
    assert_eq!(d.missing, vec!["s/gone".to_string()]);
    assert_eq!(d.added, vec!["s/fresh".to_string()]);
    assert_eq!(d.regressions(), 0, "nothing above fails the gate");

    // NaN medians (in-memory only — not representable in JSON) are
    // Incomparable too, never a gate failure
    let nan_new = doc_with(vec![("s", "boundary", f64::NAN)]);
    let d = diff_docs(&old, &nan_new, DEFAULT_THRESHOLD_PCT);
    assert_eq!(d.rows[0].status, RowStatus::Incomparable);
    assert_eq!(d.regressions(), 0);

    // one tick past the boundary is a regression
    let worse = doc_with(vec![("s", "boundary", 125.1)]);
    let d = diff_docs(&old, &worse, DEFAULT_THRESHOLD_PCT);
    assert_eq!(d.regressions(), 1);
    assert!(d.render().contains("REGRESSED"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_baselines_are_typed_errors_not_panics() {
    let dir = scratch("malformed");

    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "}{ not json").unwrap();
    assert!(matches!(BenchDoc::load(&garbage), Err(BenchError::Json(_))));

    let shape = dir.join("shape.json");
    std::fs::write(&shape, "{\"format\":2,\"rows\":[]}").unwrap();
    assert!(matches!(BenchDoc::load(&shape), Err(BenchError::Schema(_))));

    let old_format = dir.join("format1.json");
    let mut doc = doc_with(vec![("s", "a", 1.0)]);
    doc.format = 1;
    std::fs::write(&old_format, format!("{}\n", doc.to_json())).unwrap();
    match BenchDoc::load(&old_format) {
        Err(BenchError::Schema(m)) => assert!(m.contains("format 1"), "{m}"),
        other => panic!("expected schema error, got {other:?}"),
    }

    assert!(matches!(
        BenchDoc::load(&dir.join("does_not_exist.json")),
        Err(BenchError::Io(_, _))
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn quick_store_area_runs_headless_and_self_diffs_clean() {
    // The cheapest real area end-to-end: suite registry -> sampled
    // rows -> document -> file -> gate. Mirrors what CI's bench job
    // does with `bench run --quick` + `bench diff`.
    let doc = run_area("store", true).unwrap();
    assert_eq!(doc.bench, "store");
    assert!(doc.quick);
    assert!(!doc.rows.is_empty());
    assert!(
        doc.rows.iter().any(|r| r.name == "store_append_batch"),
        "expected the append row, got {:?}",
        doc.rows.iter().map(|r| r.id()).collect::<Vec<_>>()
    );
    for r in &doc.rows {
        assert!(r.median_ns.is_finite() && r.median_ns > 0.0, "{}", r.id());
    }

    let dir = scratch("selfdiff");
    let path = dir.join("BENCH_store.json");
    doc.write(&path).unwrap();
    let loaded = BenchDoc::load(&path).unwrap();
    let d = diff_docs(&loaded, &doc, DEFAULT_THRESHOLD_PCT);
    assert_eq!(d.regressions(), 0, "a run never regresses against itself");
    assert_eq!(d.missing.len() + d.added.len(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wallclock_lint_is_clean_and_timer_is_the_only_allow_site() {
    // Self-check of the PR's contract: `no-wallclock-state` now covers
    // all of src/, and the only honored allows for it are the two
    // sanctioned reads in util::timer. A new Instant::now() anywhere
    // else in src/ fails this test before CI's fedlint job sees it.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::builtin();
    let report = lint_root(root, &cfg, Some("no-wallclock-state"), &[]).unwrap();

    let denials: Vec<String> = report
        .violations
        .iter()
        .filter(|v| v.rule == "no-wallclock-state")
        .map(|v| format!("{}:{} {}", v.file, v.line, v.excerpt))
        .collect();
    assert!(denials.is_empty(), "unsanctioned wall-clock reads: {denials:?}");

    let allow_files: Vec<&str> = report
        .allowed
        .iter()
        .filter(|a| a.rules.iter().any(|r| r == "no-wallclock-state"))
        .map(|a| a.file.as_str())
        .collect();
    assert_eq!(
        allow_files,
        vec!["src/util/timer.rs", "src/util/timer.rs"],
        "timer.rs must stay the narrow waist: one allow for now(), one for unix_now_s()"
    );
    for a in &report.allowed {
        assert!(a.uses >= 1, "stale allow at {}:{}", a.file, a.line);
    }
}
