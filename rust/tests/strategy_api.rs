//! Strategy plugin API tests: registry round-trips, parallel-vs-serial
//! encode determinism, and paired-seed equivalence of the plugin
//! strategies against straight-line reference implementations of the
//! pre-refactor round loop (same RNG fork constants, no plugin
//! indirection). Engine-dependent tests skip when artifacts are absent.

use fedcompress::baselines::registry::StrategyRegistry;
use fedcompress::baselines::topk::{decode_topk, encode_topk};
use fedcompress::clustering::CentroidState;
use fedcompress::compression::codec::quantize_and_encode;
use fedcompress::compression::kmeans::kmeans_1d;
use fedcompress::compression::sparsify::magnitude_prune;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::aggregate::{fedavg, weighted_mean};
use fedcompress::coordinator::selection::select_clients;
use fedcompress::coordinator::server::{build_data, run_federated_with_data, FederatedData};
use fedcompress::coordinator::strategy::{RoundContext, UploadInput};
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;
use fedcompress::util::rng::Rng;
use fedcompress::util::threadpool::parallel_map;

fn engine() -> Option<Engine> {
    let d = default_dir();
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load(&d).unwrap())
}

fn tiny_cfg(dataset: &str) -> FedConfig {
    let mut cfg = FedConfig::quick(dataset);
    cfg.rounds = 3;
    cfg.clients = 3;
    cfg.local_epochs = 2;
    cfg.server_epochs = 1;
    cfg.train_size = 192;
    cfg.test_size = 96;
    cfg.ood_size = 64;
    cfg.unlabeled_per_client = 16;
    cfg.warmup_rounds = 1;
    cfg.validate().unwrap();
    cfg
}

// ---------------------------------------------------------------------------
// registry round-trip (no engine needed)
// ---------------------------------------------------------------------------

#[test]
fn every_registered_name_parses_and_constructs() {
    let reg = StrategyRegistry::builtin();
    let cfg = FedConfig::quick("cifar10");
    let names = reg.names();
    assert!(names.len() >= 5, "expected at least 5 builtins: {names:?}");
    for name in names {
        let strategy = reg.build(name, &cfg).unwrap();
        assert_eq!(strategy.name(), name, "name round-trip");
        // a second build is an independent instance (single-run contract)
        let again = reg.build(name, &cfg).unwrap();
        assert_eq!(again.name(), name);
    }
    // table-1 columns and the openness-proof plugin are all present
    for required in ["fedavg", "fedzip", "fedcompress-noscs", "fedcompress", "topk"] {
        assert!(reg.names().contains(&required), "{required} missing");
    }
}

#[test]
fn unknown_strategy_suggests_closest_registered_name() {
    let reg = StrategyRegistry::builtin();
    let cfg = FedConfig::quick("cifar10");
    for (typo, want) in [
        ("fedcompres", "fedcompress"),
        ("fedzipp", "fedzip"),
        ("topkk", "topk"),
    ] {
        let err = reg.build(typo, &cfg).unwrap_err().to_string();
        assert!(
            err.contains(&format!("did you mean '{want}'")),
            "typo {typo}: {err}"
        );
    }
}

// ---------------------------------------------------------------------------
// per-strategy wire-direction policy (no engine needed)
// ---------------------------------------------------------------------------

/// Table 1's byte accounting rests on which direction each strategy
/// compresses and when; assert that policy directly on the plugin
/// hooks so CI catches a flipped branch without built artifacts.
#[test]
fn wire_direction_policy_per_strategy() {
    use fedcompress::coordinator::strategy::ServerModel;

    let cfg = FedConfig::quick("cifar10");
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let reg = StrategyRegistry::builtin();
    let mut rng = Rng::new(1);
    let theta: Vec<f32> = (0..5000).map(|_| rng.normal() * 0.2).collect();
    let dense = 4 * theta.len();
    let centroids = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);
    let model = ServerModel {
        theta: theta.clone(),
        centroids: centroids.clone(),
    };
    let ctx_at = |round: usize| RoundContext {
        round,
        cfg: &cfg,
        base: &base,
        compressing: round >= cfg.warmup_rounds,
        down_compressed: round > cfg.warmup_rounds,
    };
    let warmup = ctx_at(0);
    let late = ctx_at(cfg.warmup_rounds + 2);
    let up = |s: &dyn fedcompress::coordinator::strategy::FedStrategy,
              ctx: &RoundContext<'_>| {
        let mut r = base.fork(42);
        s.encode_upload(
            ctx,
            &UploadInput {
                client: 0,
                theta: &theta,
                centroids: &centroids,
            },
            &mut r,
        )
        .unwrap()
        .bytes
    };

    // FedAvg: dense both directions, always
    let s = reg.build("fedavg", &cfg).unwrap();
    assert_eq!(s.encode_download(&late, &model).unwrap().bytes, dense);
    assert_eq!(up(&*s, &late), dense);

    // FedZip: compressed upstream only; downstream stays dense
    let s = reg.build("fedzip", &cfg).unwrap();
    assert_eq!(s.encode_download(&late, &model).unwrap().bytes, dense);
    assert!(up(&*s, &late) < dense / 3);

    // NoScs: dense on the wire even once compressing (CCR ~ 1)
    let s = reg.build("fedcompress-noscs", &cfg).unwrap();
    assert_eq!(s.encode_download(&late, &model).unwrap().bytes, dense);
    assert_eq!(up(&*s, &late), dense);

    // FedCompress: dense during warmup, compressed both ways after
    let s = reg.build("fedcompress", &cfg).unwrap();
    assert_eq!(s.encode_download(&warmup, &model).unwrap().bytes, dense);
    assert_eq!(up(&*s, &warmup), dense);
    assert!(s.encode_download(&late, &model).unwrap().bytes < dense / 4);
    assert!(up(&*s, &late) < dense / 4);

    // TopK: compressed upstream only
    let s = reg.build("topk", &cfg).unwrap();
    assert_eq!(s.encode_download(&late, &model).unwrap().bytes, dense);
    assert!(up(&*s, &late) < dense / 3);
}

// ---------------------------------------------------------------------------
// parallel_map-driven encode == serial encode (no engine needed)
// ---------------------------------------------------------------------------

/// Drive the heaviest `encode_upload` (FedZip: prune + k-means +
/// Huffman, RNG-consuming) for 8 synthetic clients serially and through
/// `parallel_map`, and require bit-identical blobs. This is the pure
/// core of the serial==parallel guarantee: per-client RNG forks make
/// the encode order-independent.
#[test]
fn parallel_encode_is_bit_identical_to_serial() {
    let cfg = FedConfig::quick("cifar10");
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let reg = StrategyRegistry::builtin();

    for name in ["fedzip", "topk", "fedcompress"] {
        let strategy = reg.build(name, &cfg).unwrap();
        let ctx = RoundContext {
            round: 3,
            cfg: &cfg,
            base: &base,
            compressing: true,
            down_compressed: true,
        };
        // synthetic trained clients: distinct thetas + forked rngs
        let clients: Vec<(Vec<f32>, CentroidState, Rng)> = (0..8)
            .map(|k| {
                let mut rng = base.fork(10_000 + k as u64);
                let theta: Vec<f32> = (0..4000).map(|_| rng.normal() * 0.2).collect();
                let cents = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);
                (theta, cents, rng)
            })
            .collect();

        let encode_one = |i: usize| {
            let (theta, cents, rng) = &clients[i];
            let mut rng = rng.clone();
            strategy
                .encode_upload(
                    &ctx,
                    &UploadInput {
                        client: i,
                        theta,
                        centroids: cents,
                    },
                    &mut rng,
                )
                .unwrap()
        };

        let serial: Vec<_> = (0..clients.len()).map(encode_one).collect();
        for workers in [1, 2, 7] {
            let parallel = parallel_map(clients.len(), workers, encode_one);
            assert_eq!(serial.len(), parallel.len());
            for (s, p) in serial.iter().zip(&parallel) {
                assert_eq!(s.bytes, p.bytes, "{name} bytes diverged at {workers} workers");
                assert_eq!(s.theta, p.theta, "{name} theta diverged at {workers} workers");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// engine-gated: whole-run serial == parallel
// ---------------------------------------------------------------------------

#[test]
fn parallel_rounds_equal_serial_rounds() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();

    for strategy in ["fedzip", "fedcompress", "topk"] {
        let mut serial_cfg = cfg.clone();
        serial_cfg.upload_workers = 1;
        let serial = run_federated_with_data(&engine, &serial_cfg, strategy, &data).unwrap();

        let mut par_cfg = cfg.clone();
        par_cfg.upload_workers = 8;
        let parallel = run_federated_with_data(&engine, &par_cfg, strategy, &data).unwrap();

        assert_eq!(serial.final_theta, parallel.final_theta, "{strategy}");
        assert_eq!(serial.total_bytes(), parallel.total_bytes(), "{strategy}");
        for (a, b) in serial.rounds.iter().zip(&parallel.rounds) {
            assert_eq!(a.accuracy, b.accuracy, "{strategy} round {}", a.round);
            assert_eq!(a.up_bytes, b.up_bytes, "{strategy} round {}", a.round);
            assert_eq!(a.down_bytes, b.down_bytes, "{strategy} round {}", a.round);
        }
    }
}

// ---------------------------------------------------------------------------
// engine-gated: plugin runs reproduce the pre-refactor loop
// ---------------------------------------------------------------------------

/// Straight-line FedAvg exactly as the pre-refactor monolithic loop
/// computed it: same RNG fork constants, dense wire, plain aggregation.
fn reference_fedavg(
    engine: &Engine,
    cfg: &FedConfig,
    data: &FederatedData,
) -> (Vec<f64>, Vec<f32>) {
    use fedcompress::client::trainer::{evaluate, train_local};
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let c_max = engine.manifest.c_max;
    let mut theta = engine.init_theta(&cfg.dataset).unwrap();
    let mut cents_rng = base.fork(2);
    let centroids =
        CentroidState::init_from_weights(&theta, cfg.controller.c_min, c_max, &mut cents_rng);

    let mut accs = Vec::new();
    for round in 0..cfg.rounds {
        let mut round_rng = base.fork(100 + round as u64);
        let selected = select_clients(cfg.clients, cfg.participation, &mut round_rng).unwrap();
        let mut thetas = Vec::new();
        let mut ns = Vec::new();
        for &k in &selected {
            let mut client_rng = base.fork(10_000 + (round * cfg.clients + k) as u64);
            let outcome = train_local(
                engine,
                cfg,
                &data.labeled[k],
                &data.unlabeled[k],
                &theta,
                &centroids,
                false,
                &mut client_rng,
            )
            .unwrap();
            ns.push(outcome.n);
            thetas.push(outcome.theta);
        }
        theta = fedavg(&thetas, &ns).unwrap();
        let (acc, _) = evaluate(engine, &cfg.dataset, &data.test, &theta).unwrap();
        accs.push(acc);
    }
    (accs, theta)
}

#[test]
fn plugin_fedavg_matches_reference_loop() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();

    let (ref_accs, ref_theta) = reference_fedavg(&engine, &cfg, &data);
    let r = run_federated_with_data(&engine, &cfg, "fedavg", &data).unwrap();

    assert_eq!(r.final_theta, ref_theta, "final model diverged");
    let accs: Vec<f64> = r.rounds.iter().map(|m| m.accuracy).collect();
    assert_eq!(accs, ref_accs, "per-round accuracy diverged");
    // dense both directions, byte-exact
    let p = ref_theta.len();
    for m in &r.rounds {
        assert_eq!(m.down_bytes, 4 * p * cfg.clients);
        assert_eq!(m.up_bytes, 4 * p * cfg.clients);
    }
    assert_eq!(r.final_model_bytes, 4 * p);
}

/// Straight-line FedZip: dense down, prune+kmeans+codec up (the RNG
/// continues from training into the k-means fit, as before the
/// refactor), FedAvg of the *decoded* uploads, fork(9_999) final fit.
fn reference_fedzip(
    engine: &Engine,
    cfg: &FedConfig,
    data: &FederatedData,
) -> (Vec<f64>, Vec<usize>, Vec<f32>, usize) {
    use fedcompress::client::trainer::{evaluate, train_local};
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let c_max = engine.manifest.c_max;
    let mut theta = engine.init_theta(&cfg.dataset).unwrap();
    let mut cents_rng = base.fork(2);
    let centroids =
        CentroidState::init_from_weights(&theta, cfg.controller.c_min, c_max, &mut cents_rng);

    let mut accs = Vec::new();
    let mut up_bytes = Vec::new();
    for round in 0..cfg.rounds {
        let mut round_rng = base.fork(100 + round as u64);
        let selected = select_clients(cfg.clients, cfg.participation, &mut round_rng).unwrap();
        let mut thetas = Vec::new();
        let mut ns = Vec::new();
        let mut scores = Vec::new();
        let mut round_up = 0usize;
        for &k in &selected {
            let mut client_rng = base.fork(10_000 + (round * cfg.clients + k) as u64);
            let outcome = train_local(
                engine,
                cfg,
                &data.labeled[k],
                &data.unlabeled[k],
                &theta,
                &centroids,
                false,
                &mut client_rng,
            )
            .unwrap();
            let mut pruned = outcome.theta.clone();
            magnitude_prune(&mut pruned, cfg.fedzip_keep);
            let (cb, _, _) = kmeans_1d(&pruned, cfg.fedzip_clusters, 25, &mut client_rng);
            let (enc, quantized) = quantize_and_encode(&pruned, &cb);
            round_up += enc.wire_bytes();
            ns.push(outcome.n);
            scores.push(outcome.score);
            thetas.push(quantized);
        }
        let _ = weighted_mean(&scores, &ns).unwrap();
        theta = fedavg(&thetas, &ns).unwrap();
        up_bytes.push(round_up);
        let (acc, _) = evaluate(engine, &cfg.dataset, &data.test, &theta).unwrap();
        accs.push(acc);
    }
    // final deliverable: fresh prune + k-means fit at fork(9_999)
    let mut rng = base.fork(9_999);
    let mut pruned = theta.clone();
    magnitude_prune(&mut pruned, cfg.fedzip_keep);
    let (cb, _, _) = kmeans_1d(&pruned, cfg.fedzip_clusters, 25, &mut rng);
    let (enc, final_theta) = quantize_and_encode(&pruned, &cb);
    (accs, up_bytes, final_theta, enc.wire_bytes())
}

#[test]
fn plugin_fedzip_matches_reference_loop() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();

    let (ref_accs, ref_up, ref_theta, ref_bytes) = reference_fedzip(&engine, &cfg, &data);
    let r = run_federated_with_data(&engine, &cfg, "fedzip", &data).unwrap();

    let accs: Vec<f64> = r.rounds.iter().map(|m| m.accuracy).collect();
    assert_eq!(accs, ref_accs, "per-round accuracy diverged");
    let ups: Vec<usize> = r.rounds.iter().map(|m| m.up_bytes).collect();
    assert_eq!(ups, ref_up, "per-round upload bytes diverged");
    assert_eq!(r.final_theta, ref_theta, "final model diverged");
    assert_eq!(r.final_model_bytes, ref_bytes, "final wire size diverged");
}

// ---------------------------------------------------------------------------
// topk wire format (no engine needed)
// ---------------------------------------------------------------------------

#[test]
fn topk_blob_decodes_to_what_the_driver_aggregates() {
    let mut rng = Rng::new(77);
    let theta: Vec<f32> = (0..6000).map(|_| rng.normal() * 0.3).collect();
    let (bytes, pruned) = encode_topk(&theta, 0.15);
    assert_eq!(decode_topk(&bytes).unwrap(), pruned);
    // survivors are exactly the top-|w| fraction
    let kept = pruned.iter().filter(|w| **w != 0.0).count();
    assert!((890..=910).contains(&kept), "{kept}");
    let min_kept = pruned
        .iter()
        .filter(|w| **w != 0.0)
        .map(|w| w.abs())
        .fold(f32::MAX, f32::min);
    let max_dropped = theta
        .iter()
        .zip(&pruned)
        .filter(|(_, p)| **p == 0.0)
        .map(|(t, _)| t.abs())
        .fold(0.0f32, f32::max);
    assert!(min_kept >= max_dropped);
}
