//! Protocol robustness: corrupt, truncated, and hostile input against
//! the frame/message codecs must surface as typed `net::ProtoError`s —
//! never a panic, never a hang, never a giant allocation. Plus a
//! no-engine handshake test over a real localhost socket.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use fedcompress::codec::StageBytes;
use fedcompress::config::FedConfig;
use fedcompress::net::frame::{self, MAX_PAYLOAD};
use fedcompress::net::proto::{Hello, HelloAck, Msg, Upload};
use fedcompress::net::{read_frame, write_frame, ProtoError, TcpServer, Transport, PROTO_VERSION};

fn ok_frame() -> Vec<u8> {
    frame::encode_frame(6, &42u32.to_le_bytes()) // RoundClose{42}
}

// ---------------------------------------------------------------------------
// frame codec corruption
// ---------------------------------------------------------------------------

#[test]
fn truncated_frames_error_at_every_cut_point() {
    let good = ok_frame();
    // every possible truncation: header, payload, checksum
    for cut in 0..good.len() {
        let err = read_frame(&mut &good[..cut]).unwrap_err();
        assert!(
            matches!(err, ProtoError::Truncated { .. }),
            "cut at {cut}: {err}"
        );
    }
    // the full frame still parses (the loop above really was the cut)
    assert!(read_frame(&mut &good[..]).is_ok());
}

#[test]
fn bad_magic_is_rejected() {
    let mut bad = ok_frame();
    bad[0] ^= 0xFF;
    match read_frame(&mut &bad[..]).unwrap_err() {
        ProtoError::BadMagic { got } => {
            assert_ne!(got, frame::MAGIC);
        }
        other => panic!("expected BadMagic, got {other}"),
    }
}

#[test]
fn wrong_version_is_rejected_with_the_peer_version() {
    let mut bad = ok_frame();
    bad[4] = 99; // version low byte
    match read_frame(&mut &bad[..]).unwrap_err() {
        ProtoError::BadVersion { got } => assert_eq!(got, 99),
        other => panic!("expected BadVersion, got {other}"),
    }
    assert_ne!(PROTO_VERSION, 99);
}

#[test]
fn crc_mismatch_is_detected_on_any_payload_flip() {
    let good = frame::encode_frame(5, b"some payload worth protecting");
    let payload_start = 11;
    let payload_end = good.len() - 4;
    for i in payload_start..payload_end {
        let mut bad = good.clone();
        bad[i] ^= 0x40;
        match read_frame(&mut &bad[..]).unwrap_err() {
            ProtoError::CrcMismatch { stored, computed } => assert_ne!(stored, computed),
            other => panic!("flip at {i}: expected CrcMismatch, got {other}"),
        }
    }
}

/// A hostile length prefix must be refused before allocation — this
/// test would OOM or hang if the cap were missing.
#[test]
fn oversized_length_is_refused_without_allocating() {
    let mut bad = Vec::new();
    bad.extend_from_slice(&frame::MAGIC.to_le_bytes());
    bad.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    bad.push(4);
    bad.extend_from_slice(&u32::MAX.to_le_bytes()); // 4 GiB claim
    match read_frame(&mut &bad[..]).unwrap_err() {
        ProtoError::Oversized { len, max } => {
            assert_eq!(len, u32::MAX);
            assert_eq!(max, MAX_PAYLOAD);
        }
        other => panic!("expected Oversized, got {other}"),
    }
}

#[test]
fn unknown_message_type_is_typed() {
    let bad = frame::encode_frame(200, b"");
    let (ty, payload) = read_frame(&mut &bad[..]).unwrap();
    match Msg::decode(ty, &payload).unwrap_err() {
        ProtoError::UnknownMsgType { got } => assert_eq!(got, 200),
        other => panic!("expected UnknownMsgType, got {other}"),
    }
}

#[test]
fn malformed_message_bodies_are_typed_not_panics() {
    // truncated body for every message type in the vocabulary
    for ty in 1u8..=6 {
        let err = Msg::decode(ty, &[0x01]).unwrap_err();
        assert!(
            matches!(err, ProtoError::Truncated { .. } | ProtoError::Malformed { .. }),
            "type {ty}: {err}"
        );
    }
    // trailing garbage after a well-formed body
    let mut body = 7u32.to_le_bytes().to_vec();
    body.push(0xEE);
    let err = Msg::decode(6, &body).unwrap_err();
    assert!(matches!(err, ProtoError::Malformed { .. }), "{err}");
    // random bytes across all types: anything but a panic
    let mut x = 0x2545F4914F6CDD1Du64;
    for _ in 0..2000 {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let len = (x % 64) as usize;
        let bytes: Vec<u8> = (0..len).map(|i| (x >> (i % 8)) as u8).collect();
        let ty = (x >> 8) as u8;
        let _ = Msg::decode(ty, &bytes); // must return, not panic
    }
}

/// The sidecar-bearing messages (Upload with its stage table and codec
/// header, HelloAck with its config image) must be robust at *every*
/// byte boundary, not just the easy prefixes — a truncated stage name
/// or a half-read f64 in the config are exactly the cuts a dying peer
/// produces.
#[test]
fn sidecar_messages_error_at_every_truncation_point() {
    let up = Msg::Upload(Upload {
        round: 3,
        client: 8,
        score: 0.75,
        n: 32,
        mean_ce: 1.25,
        mu: vec![0.5, -0.5, 2.0],
        stages: vec![
            StageBytes { stage: "topk".to_string(), bytes: 900 },
            StageBytes { stage: "huffman".to_string(), bytes: 40 },
        ],
        spec: "topk(keep=0.1)|huffman".to_string(),
        payload: vec![7u8; 16],
    });
    let body = up.encode_payload();
    // Upload swallows all trailing bytes as payload, so every strict
    // prefix must fail *up to* the point where the payload begins;
    // after that, shorter payloads still decode (just shorter).
    let payload_start = body.len() - 16;
    for cut in 0..payload_start {
        let err = Msg::decode(5, &body[..cut]).unwrap_err();
        assert!(
            matches!(err, ProtoError::Truncated { .. } | ProtoError::Malformed { .. }),
            "upload cut at {cut}: {err}"
        );
    }
    assert!(Msg::decode(5, &body).is_ok());

    let ack = Msg::HelloAck(HelloAck {
        worker: 0,
        workers: 2,
        clients: vec![0, 2, 4],
        strategy: "fedavg".to_string(),
        cfg: Box::new(FedConfig::quick("cifar10")),
    });
    let body = ack.encode_payload();
    for cut in 0..body.len() {
        let err = Msg::decode(2, &body[..cut]).unwrap_err();
        assert!(
            matches!(err, ProtoError::Truncated { .. } | ProtoError::Malformed { .. }),
            "ack cut at {cut}: {err}"
        );
    }
    assert!(Msg::decode(2, &body).is_ok());
}

/// Hostile counts and headers inside a message body are refused with a
/// typed error before any oversized allocation or bogus decode.
#[test]
fn hostile_sidecar_fields_are_typed_malformed() {
    // upload fixed head: round(4) client(4) score(8) n(4) mean_ce(4)
    let mut head = Vec::new();
    head.extend_from_slice(&1u32.to_le_bytes());
    head.extend_from_slice(&2u32.to_le_bytes());
    head.extend_from_slice(&0.5f64.to_le_bytes());
    head.extend_from_slice(&4u32.to_le_bytes());
    head.extend_from_slice(&0.1f32.to_le_bytes());
    head.extend_from_slice(&0u32.to_le_bytes()); // empty centroid table

    // stage count far over the sidecar cap
    let mut bad = head.clone();
    bad.push(255);
    let err = Msg::decode(5, &bad).unwrap_err();
    assert!(matches!(err, ProtoError::Malformed { .. }), "{err}");
    assert!(err.to_string().contains("over the cap"), "{err}");

    // stage name that is not utf-8
    let mut bad = head.clone();
    bad.push(1); // one stage
    bad.push(2); // name_len
    bad.extend_from_slice(&[0xFF, 0xFE]);
    bad.extend_from_slice(&0u64.to_le_bytes());
    let err = Msg::decode(5, &bad).unwrap_err();
    assert!(err.to_string().contains("utf-8"), "{err}");

    // codec header from a future build
    let mut bad = head.clone();
    bad.push(0); // no stages
    bad.push(99); // codec header version
    let err = Msg::decode(5, &bad).unwrap_err();
    assert!(err.to_string().contains("codec header version 99"), "{err}");

    // empty codec spec names no pipeline
    let mut bad = head;
    bad.push(0); // no stages
    bad.push(1); // codec header version
    bad.extend_from_slice(&0u16.to_le_bytes()); // spec_len = 0
    let err = Msg::decode(5, &bad).unwrap_err();
    assert!(err.to_string().contains("empty codec spec"), "{err}");

    // a handshake granting two million clients is a corrupt peer, not
    // a reason to allocate 8 MB
    let mut bad = Vec::new();
    bad.extend_from_slice(&0u32.to_le_bytes());
    bad.extend_from_slice(&1u32.to_le_bytes());
    bad.extend_from_slice(&2_000_000u32.to_le_bytes());
    let err = Msg::decode(2, &bad).unwrap_err();
    assert!(err.to_string().contains("2000000 clients"), "{err}");

    // a round open claiming more active centroids than it ships
    let mut bad = Vec::new();
    bad.extend_from_slice(&1u32.to_le_bytes()); // round
    bad.extend_from_slice(&0u32.to_le_bytes()); // n_downloads
    bad.push(0); // flags
    bad.extend_from_slice(&5u32.to_le_bytes()); // active = 5
    bad.extend_from_slice(&0u32.to_le_bytes()); // ...of 0 centroids
    let err = Msg::decode(3, &bad).unwrap_err();
    assert!(err.to_string().contains("5 active"), "{err}");
}

#[test]
fn proto_errors_format_usefully() {
    let e = ProtoError::CrcMismatch {
        stored: 0xDEAD,
        computed: 0xBEEF,
    };
    assert!(e.to_string().contains("0x0000dead"), "{e}");
    assert!(ProtoError::BadVersion { got: 3 }.to_string().contains("v3"));
    assert!(ProtoError::Truncated { what: "frame header" }
        .to_string()
        .contains("frame header"));
    // timeouts are distinguishable from dead peers
    let timeout = ProtoError::Io(std::io::Error::from(std::io::ErrorKind::WouldBlock));
    assert!(timeout.is_timeout());
    let eof = ProtoError::Io(std::io::Error::from(std::io::ErrorKind::UnexpectedEof));
    assert!(!eof.is_timeout());
}

// ---------------------------------------------------------------------------
// handshake over a real localhost socket (no engine needed)
// ---------------------------------------------------------------------------

/// Bind on port 0, connect a hand-rolled peer speaking the raw
/// protocol, and check the handshake grant: deterministic client ids,
/// a bit-exact config image, and a clean Shutdown.
#[test]
fn handshake_grants_ids_and_config_over_tcp() {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.set("clients", "5").unwrap();
    cfg.set("seed", "1234").unwrap();
    let server = TcpServer::bind("127.0.0.1:0", 2, &cfg, "topk", None).unwrap();
    let addr = server.local_addr().unwrap();

    let fake_worker = |expect_ids: Vec<u32>| {
        thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            Msg::Hello(Hello {
                proto_version: PROTO_VERSION,
                edge_of: 0,
            })
            .write_to(&mut &stream)
            .unwrap();
            let ack = match Msg::read_from(&mut &stream).unwrap() {
                Msg::HelloAck(a) => a,
                other => panic!("expected HelloAck, got {}", other.kind()),
            };
            assert_eq!(ack.workers, 2);
            assert_eq!(ack.clients, expect_ids);
            assert_eq!(ack.strategy, "topk");
            assert_eq!(ack.cfg.clients, 5);
            assert_eq!(ack.cfg.seed, 1234);
            assert_eq!(format!("{:?}", ack.cfg), format!("{:?}", make_cfg()));
            // wait for the shutdown frame
            match Msg::read_from(&mut &stream).unwrap() {
                Msg::Shutdown => {}
                other => panic!("expected Shutdown, got {}", other.kind()),
            }
        })
    };
    fn make_cfg() -> FedConfig {
        let mut cfg = FedConfig::quick("cifar10");
        cfg.set("clients", "5").unwrap();
        cfg.set("seed", "1234").unwrap();
        cfg
    }

    // worker 0 hosts {0, 2, 4}, worker 1 hosts {1, 3} — arrival order
    let h0 = fake_worker(vec![0, 2, 4]);
    thread::sleep(Duration::from_millis(100)); // pin arrival order
    let h1 = fake_worker(vec![1, 3]);

    let mut transport = server.accept_workers().unwrap();
    assert_eq!(transport.alive_workers(), 2);
    assert!(transport.control_bytes() > 0, "handshake traffic is control-plane");
    transport.shutdown().unwrap();
    h0.join().unwrap();
    h1.join().unwrap();
}

/// A peer that is not speaking the protocol cannot wedge OR abort the
/// handshake: its connection is logged and dropped, and the listener
/// keeps accepting until a real worker completes the grant — the
/// port-scanner robustness contract.
#[test]
fn garbage_handshake_is_dropped_and_accepting_continues() {
    let cfg = FedConfig::quick("cifar10");
    let server = TcpServer::bind("127.0.0.1:0", 1, &cfg, "fedavg", None).unwrap();
    let addr = server.local_addr().unwrap();
    // two hostile peers land first: an HTTP probe and a connect+close
    let probe = thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // server hangs up on us; drain until EOF
        let mut sink = Vec::new();
        let _ = stream.read_to_end(&mut sink);
    });
    let closer = thread::spawn(move || {
        drop(TcpStream::connect(addr).unwrap());
    });
    thread::sleep(Duration::from_millis(100)); // pin arrival order
    let real = thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        Msg::Hello(Hello {
            proto_version: PROTO_VERSION,
            edge_of: 0,
        })
        .write_to(&mut &stream)
        .unwrap();
        let ack = match Msg::read_from(&mut &stream).unwrap() {
            Msg::HelloAck(a) => a,
            other => panic!("expected HelloAck, got {}", other.kind()),
        };
        // the real worker still receives the full single-worker grant
        assert_eq!(ack.worker, 0);
        assert_eq!(ack.workers, 1);
        assert_eq!(ack.clients.len(), ack.cfg.clients);
        match Msg::read_from(&mut &stream).unwrap() {
            Msg::Shutdown => {}
            other => panic!("expected Shutdown, got {}", other.kind()),
        }
    });
    let mut transport = server.accept_workers().unwrap();
    assert_eq!(transport.alive_workers(), 1);
    transport.shutdown().unwrap();
    probe.join().unwrap();
    closer.join().unwrap();
    real.join().unwrap();
}

/// `write_frame`/`read_frame` are inverse over a socket, not just a
/// buffer (exactly what the worker loop relies on).
#[test]
fn frames_survive_a_real_socket() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let (ty, payload) = read_frame(&mut &stream).unwrap();
        write_frame(&mut &stream, ty, &payload).unwrap(); // echo
    });
    let stream = TcpStream::connect(addr).unwrap();
    let payload: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
    let wrote = write_frame(&mut &stream, 4, &payload).unwrap();
    assert_eq!(wrote, frame::framed_len(payload.len()));
    let (ty, echoed) = read_frame(&mut &stream).unwrap();
    assert_eq!(ty, 4);
    assert_eq!(echoed, payload);
    h.join().unwrap();
}
