//! fedlint end-to-end: fixture files with known violations per rule,
//! the allow/suppression contract, scope boundaries, lexer robustness
//! under random inputs, and the self-lint gate — the committed tree
//! must be clean under the committed `fedlint.toml`.
//!
//! The fixtures under `tests/lint_fixtures/` are data, not compiled
//! code (cargo only builds top-level `tests/*.rs`); each one documents
//! its expected hits in its header.

use std::path::Path;

use fedcompress::check::{ensure, forall, FnGen};
use fedcompress::lint::{self, lexer, LintConfig, Severity};
use fedcompress::util::json::Json;
use fedcompress::util::rng::Rng;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Every registered rule at deny over `src/` — fixtures are linted as
/// if they lived at `src/fake/<name>`.
fn deny_all() -> LintConfig {
    let rules = lint::rule_names()
        .iter()
        .map(|r| format!("[rule.{r}]\nseverity = \"deny\"\npaths = [\"src/\"]\n"))
        .collect::<String>();
    LintConfig::parse(&rules).unwrap()
}

fn lint_fixture(name: &str) -> (Vec<lint::Violation>, Vec<lint::AllowedSite>) {
    let rel = format!("src/fake/{name}");
    lint::lint_source(&rel, &fixture(name), &deny_all(), None)
}

fn hits(v: &[lint::Violation], rule: &str) -> Vec<u32> {
    v.iter().filter(|x| x.rule == rule).map(|x| x.line).collect()
}

#[test]
fn det_map_iter_fixture_hits_expected_lines() {
    let (v, allowed) = lint_fixture("det_map_iter.rs");
    assert_eq!(hits(&v, "det-map-iter"), vec![6, 7, 13], "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (9, 1));
}

#[test]
fn no_panic_decode_fixture_hits_expected_lines() {
    let (v, allowed) = lint_fixture("no_panic_decode.rs");
    assert_eq!(hits(&v, "no-panic-decode"), vec![6, 7, 8, 10, 12], "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (15, 1));
}

#[test]
fn no_wallclock_fixture_hits_expected_lines() {
    let (v, allowed) = lint_fixture("no_wallclock.rs");
    assert_eq!(hits(&v, "no-wallclock-state"), vec![8, 9], "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (13, 1));
}

#[test]
fn rng_discipline_fixture_hits_expected_lines() {
    let (v, allowed) = lint_fixture("rng_discipline.rs");
    assert_eq!(hits(&v, "rng-discipline"), vec![6], "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (11, 1));
}

#[test]
fn float_order_fixture_hits_expected_lines() {
    let (v, allowed) = lint_fixture("float_order.rs");
    assert_eq!(hits(&v, "float-order"), vec![6, 8], "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (12, 1));
}

#[test]
fn unsafe_scope_fixture_hits_expected_lines() {
    let (v, allowed) = lint_fixture("unsafe_scope.rs");
    assert_eq!(hits(&v, "unsafe-scope"), vec![6, 9], "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (12, 1));
    // outside the backend carve-out the message names the sanctioned scope
    assert!(
        v.iter().all(|x| x.message.contains("backend")),
        "{v:?}"
    );
}

#[test]
fn unsafe_scope_backend_files_still_require_reasoned_allows() {
    // the same source under src/kernels/backend_*.rs: the rule still
    // fires per site (only the allow discharges it), with the backend
    // wording; a properly argued allow suppresses exactly one site
    let src = "pub fn f(p: *const u32) -> u32 {\n\
               // fedlint:allow(unsafe-scope) -- caller keeps p in bounds\n\
               unsafe { p.read() }\n\
               }\n\
               pub fn g(p: *const u32) -> u32 { unsafe { p.read() } }\n";
    let (v, allowed) = lint::lint_source("src/kernels/backend_avx2.rs", src, &deny_all(), None);
    assert_eq!(hits(&v, "unsafe-scope"), vec![5], "{v:?}");
    assert!(v[0].message.contains("safety argument"), "{v:?}");
    assert_eq!(allowed.len(), 1, "{allowed:?}");
    assert_eq!((allowed[0].line, allowed[0].uses), (2, 1));
}

#[test]
fn clean_fixture_is_clean() {
    let (v, allowed) = lint_fixture("clean.rs");
    assert!(v.is_empty(), "{v:?}");
    assert!(allowed.is_empty(), "{allowed:?}");
}

#[test]
fn bad_allow_fixture_reports_contract_violations() {
    let (v, allowed) = lint_fixture("bad_allow.rs");
    assert!(allowed.is_empty(), "broken allows must not be honored: {allowed:?}");
    assert_eq!(hits(&v, "bad-allow"), vec![5, 8], "{v:?}");
    assert_eq!(hits(&v, "unused-allow"), vec![11], "{v:?}");
    for x in &v {
        match x.rule.as_str() {
            "bad-allow" => assert_eq!(x.severity, Severity::Deny, "bad-allow always gates"),
            "unused-allow" => assert_eq!(x.severity, Severity::Warn),
            other => panic!("unexpected rule {other}: {v:?}"),
        }
    }
}

#[test]
fn scope_boundaries_gate_every_fixture() {
    // the same bytes outside the configured scope produce nothing
    let cfg = deny_all();
    for name in [
        "det_map_iter.rs",
        "no_panic_decode.rs",
        "no_wallclock.rs",
        "rng_discipline.rs",
        "float_order.rs",
        "unsafe_scope.rs",
        "bad_allow.rs",
    ] {
        let src = fixture(name);
        let rel = format!("tests/lint_fixtures/{name}");
        let (v, allowed) = lint::lint_source(&rel, &src, &cfg, None);
        assert!(v.is_empty(), "{name} out of scope fired: {v:?}");
        assert!(allowed.is_empty(), "{name} out of scope honored allows");
    }
    // directory-prefix vs exact-file scopes
    let exact = LintConfig::parse(
        "[rule.det-map-iter]\nseverity = \"deny\"\npaths = [\"src/net/proto.rs\"]\n",
    )
    .unwrap();
    let src = "use std::collections::HashMap;\n";
    assert_eq!(lint::lint_source("src/net/proto.rs", src, &exact, None).0.len(), 1);
    assert!(lint::lint_source("src/net/frame.rs", src, &exact, None).0.is_empty());
}

/// Point `lint_root` at the fixture tree: diagnostics must carry
/// file:line, the report must gate, and the JSON must round-trip.
#[test]
fn lint_root_reports_fixture_violations_with_file_and_line() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::parse(
        "[rule.det-map-iter]\nseverity = \"deny\"\npaths = [\"tests/lint_fixtures/\"]\n",
    )
    .unwrap();
    let report = lint::lint_root(root, &cfg, None, &[]).unwrap();
    assert!(!report.is_clean());
    let first = report
        .violations
        .iter()
        .find(|v| v.rule == "det-map-iter")
        .expect("fixture violation surfaced");
    assert_eq!(first.file, "tests/lint_fixtures/det_map_iter.rs");
    assert_eq!(first.line, 6);
    assert!(first.excerpt.contains("HashMap"), "{first:?}");

    let text = lint::render_text(&report);
    assert!(text.contains("tests/lint_fixtures/det_map_iter.rs:6"), "{text}");

    let parsed = Json::parse(&lint::render_json(&report)).unwrap();
    assert!(parsed.get("deny").unwrap().as_usize().unwrap() >= 3);
    let v = parsed.get("violations").unwrap().as_arr().unwrap();
    assert!(!v.is_empty());
    assert!(v[0].get("file").unwrap().as_str().is_ok());

    // path filters narrow the scan to one file
    let only = lint::lint_root(
        root,
        &cfg,
        None,
        &["tests/lint_fixtures/clean.rs".to_string()],
    )
    .unwrap();
    assert!(only.violations.is_empty(), "{:?}", only.violations);
    assert_eq!(only.files_scanned, 1);
}

/// The gate itself: the committed tree is clean under the committed
/// config, and the allows in the tree are all real (each suppresses at
/// least one live violation — stale ones would surface as warnings).
#[test]
fn the_committed_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::from_file(&root.join("fedlint.toml"))
        .expect("committed fedlint.toml parses");
    let report = lint::lint_root(root, &cfg, None, &[]).expect("lint runs");
    let gate: Vec<String> = report
        .violations
        .iter()
        .map(|v| format!("{}:{}: [{}] {}: {}", v.file, v.line, v.severity.name(), v.rule, v.message))
        .collect();
    assert!(
        report.violations.is_empty(),
        "self-lint violations:\n{}",
        gate.join("\n")
    );
    assert!(report.files_scanned > 10, "scanned only {}", report.files_scanned);
    assert!(
        !report.allowed.is_empty(),
        "the tree documents its exceptions via reasoned allows"
    );
}

/// Random token soup must never panic the lexer, and its line
/// numbering must stay sane — the linter runs on every CI build, so
/// robustness here is part of the gate.
#[test]
fn lexer_never_panics_on_random_input() {
    let pool: Vec<char> =
        "abrcz_09 \t\n\"'\\/*()[]{}<>:;.,#!|&-=+".chars().collect();
    let gen = FnGen(move |rng: &mut Rng, size: usize| {
        let n = rng.below(size.max(1) + 1);
        (0..n).map(|_| pool[rng.below(pool.len())]).collect::<String>()
    });
    forall(300, 0xF3D7, &gen, |s: &String| {
        let lexed = lexer::lex(s);
        ensure(
            lexed.toks.len() <= s.chars().count().max(1),
            "every token consumes at least one char",
        )?;
        ensure(
            lexed.toks.windows(2).all(|w| w[0].line <= w[1].line),
            "token lines are monotone",
        )?;
        let max_line = s.lines().count().max(1) as u32 + 1;
        ensure(
            lexed.toks.iter().all(|t| t.line >= 1 && t.line <= max_line),
            "token lines in range",
        )
    });
}
