//! Fleet simulation layer tests.
//!
//! No-engine tests cover the config surface and the sim invariants the
//! coordinator depends on; engine-gated tests (skipped without built
//! artifacts, like every other e2e suite here) cover the two headline
//! guarantees: the default fleet is zero-cost (byte-identical runs) and
//! fault injection is bit-reproducible for a fixed seed.

use fedcompress::compression::accounting::Direction;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::selection::select_clients;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;
use fedcompress::sim::{ClientFate, FleetConfig, FleetPreset, FleetSim};
use fedcompress::util::rng::Rng;

fn engine() -> Option<Engine> {
    let d = default_dir();
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load(&d).unwrap())
}

fn tiny_cfg(dataset: &str) -> FedConfig {
    let mut cfg = FedConfig::quick(dataset);
    cfg.rounds = 4;
    cfg.clients = 3;
    cfg.local_epochs = 2;
    cfg.server_epochs = 1;
    cfg.train_size = 192;
    cfg.test_size = 96;
    cfg.ood_size = 64;
    cfg.unlabeled_per_client = 16;
    cfg.warmup_rounds = 1;
    cfg.validate().unwrap();
    cfg
}

// ---------------------------------------------------------------------------
// config surface (no engine needed)
// ---------------------------------------------------------------------------

#[test]
fn default_config_carries_the_ideal_fleet() {
    assert!(FedConfig::quick("cifar10").fleet.is_ideal());
    assert!(FedConfig::paper("cifar10").fleet.is_ideal());
    assert_eq!(FedConfig::quick("cifar10").fleet, FleetConfig::default());
}

#[test]
fn fleet_flags_flow_through_config_sets() {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.set("fleet", "hostile").unwrap();
    cfg.set("dropout", "0.25").unwrap();
    cfg.set("deadline_s", "45.5").unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.fleet.preset, FleetPreset::Hostile);
    assert_eq!(cfg.fleet.dropout, 0.25);
    assert_eq!(cfg.fleet.deadline_s, 45.5);
    assert!(!cfg.fleet.is_ideal());
    assert!(cfg.set("fleet", "galactic").is_err());
}

/// The coordinator's core assumption: sim randomness comes from
/// dedicated streams, so consulting the schedule perturbs nothing.
#[test]
fn ideal_sim_never_perturbs_and_faulty_sim_is_reproducible() {
    let ideal = FleetSim::new(&FleetConfig::default(), 6, 42, 1.0);
    for round in 0..30 {
        for k in 0..6 {
            assert_eq!(ideal.fate(round, k), ClientFate::Healthy { slowdown: 1.0 });
        }
    }

    let faulty_cfg = FleetConfig {
        preset: FleetPreset::Mobile,
        dropout: 0.3,
        deadline_s: 0.0,
        edge_of: 0,
    };
    let a = FleetSim::new(&faulty_cfg, 6, 42, 1.0);
    let b = FleetSim::new(&faulty_cfg, 6, 42, 1.0);
    let mut drops = 0;
    for round in 0..30 {
        for k in 0..6 {
            assert_eq!(a.fate(round, k), b.fate(round, k));
            drops += usize::from(a.fate(round, k).is_drop());
        }
    }
    assert!(drops > 0, "a 30% dropout fleet must drop someone in 180 draws");
}

// ---------------------------------------------------------------------------
// engine-gated: the zero-cost-default invariant
// ---------------------------------------------------------------------------

/// A run with the default (untouched) fleet config must be
/// byte-identical to a run whose fleet was explicitly set to the ideal
/// preset — and must carry no fault events. (Equality with the *pre-PR*
/// loop is separately pinned by the reference-loop tests in
/// `strategy_api.rs`, which run through the sim-threaded coordinator.)
#[test]
fn ideal_fleet_runs_are_byte_identical_to_default_runs() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    assert!(cfg.fleet.is_ideal());

    let mut explicit = cfg.clone();
    explicit.set("fleet", "ideal").unwrap();
    explicit.set("dropout", "0").unwrap();
    explicit.set("deadline_s", "0").unwrap();

    for strategy in ["fedavg", "fedcompress"] {
        let d1 = build_data(&engine, &cfg).unwrap();
        let r1 = run_federated_with_data(&engine, &cfg, strategy, &d1).unwrap();
        let d2 = build_data(&engine, &explicit).unwrap();
        let r2 = run_federated_with_data(&engine, &explicit, strategy, &d2).unwrap();

        assert_eq!(r1.final_theta, r2.final_theta, "{strategy}");
        assert_eq!(r1.final_accuracy, r2.final_accuracy, "{strategy}");
        assert_eq!(r1.total_bytes(), r2.total_bytes(), "{strategy}");
        assert_eq!(r1.events.to_jsonl(), r2.events.to_jsonl(), "{strategy}");
        for (a, b) in r1.rounds.iter().zip(&r2.rounds) {
            assert_eq!(a.accuracy, b.accuracy, "{strategy}");
            assert_eq!(a.client_mean_ce, b.client_mean_ce, "{strategy}");
            assert_eq!(a.up_bytes, b.up_bytes, "{strategy}");
            assert_eq!(a.down_bytes, b.down_bytes, "{strategy}");
            assert_eq!(a.round_sim_ms, b.round_sim_ms, "{strategy}");
        }

        // an ideal fleet never faults, straggles, or misses deadlines,
        // and every selected client survives to aggregation
        assert_eq!(r1.events.of_kind("dropout").count(), 0, "{strategy}");
        assert_eq!(r1.events.of_kind("deadline").count(), 0, "{strategy}");
        for m in &r1.rounds {
            assert_eq!(m.dropped, 0, "{strategy}");
            assert_eq!(m.stragglers, 0, "{strategy}");
            assert!(m.round_sim_ms > 0.0, "{strategy}: sim clock must tick");
        }
    }
}

// ---------------------------------------------------------------------------
// engine-gated: fault injection
// ---------------------------------------------------------------------------

/// Dropout runs are bit-reproducible for a fixed seed, and the emitted
/// dropout events agree exactly with an independently rebuilt schedule.
#[test]
fn dropout_runs_are_bit_reproducible_and_match_the_schedule() {
    let Some(engine) = engine() else { return };
    let mut cfg = tiny_cfg("cifar10");
    cfg.set("fleet", "mobile").unwrap();
    cfg.set("dropout", "0.3").unwrap();

    let d1 = build_data(&engine, &cfg).unwrap();
    let r1 = run_federated_with_data(&engine, &cfg, "fedcompress", &d1).unwrap();
    let d2 = build_data(&engine, &cfg).unwrap();
    let r2 = run_federated_with_data(&engine, &cfg, "fedcompress", &d2).unwrap();

    assert_eq!(r1.final_theta, r2.final_theta);
    assert_eq!(r1.total_bytes(), r2.total_bytes());
    assert_eq!(r1.events.to_jsonl(), r2.events.to_jsonl());

    // replay selection + schedule offline and predict the drops
    let sim = FleetSim::new(&cfg.fleet, cfg.clients, cfg.seed, 1.0);
    let base = Rng::new(cfg.seed ^ 0xFEDC);
    let mut predicted: Vec<(usize, usize)> = Vec::new();
    for round in 0..cfg.rounds {
        let mut round_rng = base.fork(100 + round as u64);
        let selected = select_clients(cfg.clients, cfg.participation, &mut round_rng).unwrap();
        for &k in &selected {
            if sim.fate(round, k).is_drop() {
                predicted.push((round, k));
            }
        }
    }
    let observed: Vec<(usize, usize)> = r1
        .events
        .of_kind("dropout")
        .map(|e| {
            let j = e.to_json();
            (
                j.get("round").unwrap().as_usize().unwrap(),
                j.get("client").unwrap().as_usize().unwrap(),
            )
        })
        .collect();
    assert_eq!(observed, predicted, "dropout events must match the schedule");
    assert!(!predicted.is_empty(), "a 30% dropout run should drop someone");

    // survivors-only accounting: dropped uploads never hit the ledger
    // (participation is 1.0, so every round selects all clients)
    let dropped_total: usize = r1.rounds.iter().map(|m| m.dropped).sum();
    let survivors = cfg.rounds * cfg.clients - dropped_total;
    assert_eq!(r1.events.of_kind("upload").count(), survivors);
    assert!(r1.ledger.bytes_in(Direction::Up) > 0);
}

/// An impossible deadline cuts every client: no uploads, the model
/// never moves, and the round clock reports exactly the deadline.
#[test]
fn impossible_deadline_stalls_training() {
    let Some(engine) = engine() else { return };
    let mut cfg = tiny_cfg("cifar10");
    cfg.set("fleet", "mobile").unwrap();
    cfg.set("deadline_s", "0.000001").unwrap();

    let data = build_data(&engine, &cfg).unwrap();
    let r = run_federated_with_data(&engine, &cfg, "fedavg", &data).unwrap();

    assert_eq!(r.ledger.bytes_in(Direction::Up), 0, "no upload can make it");
    assert!(r.events.of_kind("deadline").count() > 0);
    for m in &r.rounds {
        assert_eq!(m.up_bytes, 0);
        assert_eq!(m.dropped, cfg.clients, "every selected client is lost");
        assert!((m.round_sim_ms - 1e3 * cfg.fleet.deadline_s).abs() < 1e-9);
        // the model the server evaluates never changes
        assert_eq!(m.accuracy, r.rounds[0].accuracy);
    }
}

// ---------------------------------------------------------------------------
// engine-gated: the in-process edge tier
// ---------------------------------------------------------------------------

/// `edge_of > 0` routes the in-process transport through the same
/// pre-fold/`resolve_edge` path a TCP edge worker uses. The run stays
/// deterministic, and — because members ship the same dense blobs
/// either way — a `fedavg` edge run's byte accounting matches the flat
/// run exactly; only the fold tree (and hence theta) changes.
#[test]
fn edge_tier_runs_are_deterministic_and_ledger_flat_comparable() {
    let Some(engine) = engine() else { return };
    let flat_cfg = tiny_cfg("cifar10");
    let mut edge_cfg = flat_cfg.clone();
    edge_cfg.set("edge_of", "2").unwrap(); // 3 clients -> groups of 2 + 1
    assert!(!edge_cfg.fleet.is_ideal());

    let data = build_data(&engine, &edge_cfg).unwrap();
    let e1 = run_federated_with_data(&engine, &edge_cfg, "fedavg", &data).unwrap();
    let e2 = run_federated_with_data(&engine, &edge_cfg, "fedavg", &data).unwrap();
    assert_eq!(e1.final_theta, e2.final_theta);
    assert_eq!(e1.events.to_jsonl(), e2.events.to_jsonl());
    assert_eq!(e1.total_bytes(), e2.total_bytes());

    let flat = run_federated_with_data(&engine, &flat_cfg, "fedavg", &data).unwrap();
    assert_eq!(
        e1.ledger.bytes_in(Direction::Up),
        flat.ledger.bytes_in(Direction::Up),
        "dense uploads are size-constant, so the ledger is tier-invariant"
    );
    assert_eq!(
        e1.ledger.bytes_in(Direction::Down),
        flat.ledger.bytes_in(Direction::Down)
    );
    assert_eq!(
        e1.events.of_kind("upload").count(),
        flat.events.of_kind("upload").count(),
        "an ideal fleet loses nobody, tiered or not"
    );
    for m in &e1.rounds {
        assert_eq!(m.dropped, 0);
        assert_eq!(m.stragglers, 0);
        assert!(m.round_sim_ms > 0.0);
    }
}

/// The question the sim exists to answer: on a bandwidth-bound fleet,
/// compression must buy simulated wall-clock against dense FedAvg.
#[test]
fn compression_buys_simulated_time_on_mobile_fleets() {
    let Some(engine) = engine() else { return };
    let mut cfg = tiny_cfg("cifar10");
    cfg.set("fleet", "mobile").unwrap();

    let data = build_data(&engine, &cfg).unwrap();
    let fedavg = run_federated_with_data(&engine, &cfg, "fedavg", &data).unwrap();
    let fedcmp = run_federated_with_data(&engine, &cfg, "fedcompress", &data).unwrap();

    // fates are strategy-independent, so the comparison is paired:
    // fewer bytes through the same pipes must not be slower
    assert!(
        fedcmp.total_sim_ms() < fedavg.total_sim_ms(),
        "{} vs {}",
        fedcmp.total_sim_ms(),
        fedavg.total_sim_ms()
    );
    for m in &fedavg.rounds {
        assert!(m.round_sim_ms > 0.0);
    }
}
