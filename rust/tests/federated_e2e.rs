//! End-to-end federated smoke tests: tiny runs of every strategy
//! through the full stack (PJRT execution included). Skipped when
//! artifacts are absent.

use fedcompress::compression::accounting::{ccr, Direction};
use fedcompress::config::FedConfig;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::exp::table1::COLUMNS as TABLE1;
use fedcompress::runtime::artifacts::default_dir;
use fedcompress::runtime::Engine;

fn engine() -> Option<Engine> {
    let d = default_dir();
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load(&d).unwrap())
}

fn tiny_cfg(dataset: &str) -> FedConfig {
    let mut cfg = FedConfig::quick(dataset);
    cfg.rounds = 4;
    cfg.clients = 3;
    cfg.local_epochs = 2;
    cfg.server_epochs = 1;
    cfg.train_size = 192;
    cfg.test_size = 96;
    cfg.ood_size = 64;
    cfg.unlabeled_per_client = 16;
    cfg.warmup_rounds = 1;
    cfg.validate().unwrap();
    cfg
}

#[test]
fn all_strategies_complete_and_account_bytes() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();

    let mut results = Vec::new();
    for strategy in TABLE1 {
        let r = run_federated_with_data(&engine, &cfg, strategy, &data).unwrap();
        assert_eq!(r.rounds.len(), cfg.rounds, "{strategy}");
        // every round moved bytes in both directions
        for m in &r.rounds {
            assert!(m.up_bytes > 0 && m.down_bytes > 0);
            assert!(m.accuracy.is_finite() && m.score >= 1.0);
        }
        assert!(r.ledger.bytes_in(Direction::Up) > 0);
        assert!(r.ledger.bytes_in(Direction::Down) > 0);
        assert!(r.final_accuracy.is_finite());
        assert!(r.mcr() >= 0.99, "{strategy}: mcr {}", r.mcr());
        results.push(r);
    }

    // wire-format claims, paired on identical data:
    let fedavg = &results[0];
    let fedzip = &results[1];
    let noscs = &results[2];
    let fedcmp = &results[3];

    // FedZip compresses only upstream
    assert!(fedzip.ledger.bytes_in(Direction::Up) < fedavg.ledger.bytes_in(Direction::Up));
    assert_eq!(
        fedzip.ledger.bytes_in(Direction::Down),
        fedavg.ledger.bytes_in(Direction::Down)
    );
    // w/o SCS the wire is dense (CCR ~ 1)
    let r = ccr(&fedavg.ledger, &noscs.ledger);
    assert!((0.95..=1.05).contains(&r), "noscs CCR {r}");
    // FedCompress beats FedZip on total communication
    assert!(
        fedcmp.total_bytes() < fedzip.total_bytes(),
        "{} vs {}",
        fedcmp.total_bytes(),
        fedzip.total_bytes()
    );
    // and its model ships smaller than FedAvg's
    assert!(fedcmp.final_model_bytes < fedavg.final_model_bytes / 3);
}

#[test]
fn topk_plugin_runs_end_to_end() {
    // the openness proof: a strategy registered outside the original
    // four runs through the untouched coordinator
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let data = build_data(&engine, &cfg).unwrap();
    let fedavg = run_federated_with_data(&engine, &cfg, "fedavg", &data).unwrap();
    let topk = run_federated_with_data(&engine, &cfg, "topk", &data).unwrap();
    assert_eq!(topk.rounds.len(), cfg.rounds);
    assert_eq!(topk.strategy, "topk");
    // top-k compresses upstream only
    assert!(topk.ledger.bytes_in(Direction::Up) < fedavg.ledger.bytes_in(Direction::Up) / 3);
    assert_eq!(
        topk.ledger.bytes_in(Direction::Down),
        fedavg.ledger.bytes_in(Direction::Down)
    );
    assert!(topk.mcr() > 2.0, "mcr {}", topk.mcr());
    assert!(topk.final_accuracy.is_finite());
}

#[test]
fn audio_domain_runs_end_to_end() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("voxforge");
    let data = build_data(&engine, &cfg).unwrap();
    let r = run_federated_with_data(&engine, &cfg, "fedcompress", &data).unwrap();
    assert_eq!(r.rounds.len(), cfg.rounds);
    assert!(r.final_accuracy > 0.05); // above random-ish floor (6 classes)
}

#[test]
fn deterministic_given_seed() {
    let Some(engine) = engine() else { return };
    let cfg = tiny_cfg("cifar10");
    let d1 = build_data(&engine, &cfg).unwrap();
    let r1 = run_federated_with_data(&engine, &cfg, "fedcompress", &d1).unwrap();
    let d2 = build_data(&engine, &cfg).unwrap();
    let r2 = run_federated_with_data(&engine, &cfg, "fedcompress", &d2).unwrap();
    assert_eq!(r1.final_theta, r2.final_theta);
    assert_eq!(r1.total_bytes(), r2.total_bytes());
    let mut cfg3 = cfg.clone();
    cfg3.seed = 43;
    let d3 = build_data(&engine, &cfg3).unwrap();
    let r3 = run_federated_with_data(&engine, &cfg3, "fedcompress", &d3).unwrap();
    assert_ne!(r1.final_theta, r3.final_theta);
}

#[test]
fn partial_participation_works() {
    let Some(engine) = engine() else { return };
    let mut cfg = tiny_cfg("pathmnist");
    cfg.clients = 6;
    cfg.participation = 0.5;
    cfg.train_size = 384;
    let data = build_data(&engine, &cfg).unwrap();
    let r = run_federated_with_data(&engine, &cfg, "fedavg", &data).unwrap();
    // 3 of 6 clients per round -> downstream counts 3 dispatches
    let p = engine.manifest.dataset("pathmnist").unwrap().spec.param_count;
    assert_eq!(r.rounds[0].down_bytes, 3 * 4 * p);
}
