//! API-compatible stand-in for the `xla` PJRT binding crate.
//!
//! The fedcompress coordinator talks to XLA through a narrow surface:
//! literal construction/conversion, HLO-text loading, compilation, and
//! execution. This crate implements the *literal* half functionally in
//! pure rust (so conversion code and its tests run everywhere) and
//! stubs the *runtime* half: `PjRtClient::cpu()` reports that no native
//! PJRT runtime is linked. Since every engine-dependent test and driver
//! first checks that the AOT artifacts exist, the stub keeps the whole
//! workspace building and testable on machines without an XLA
//! toolchain. Deployments with the real binding replace the `vendor/`
//! path dependency in `Cargo.toml`.

use std::fmt;

const STUB_MSG: &str = "xla vendor stub: no native PJRT runtime is linked into this build \
     (replace the vendor/xla path dependency with the real xla binding)";

#[derive(Debug)]
pub enum Error {
    /// The native runtime is not available in this build.
    Unavailable(&'static str),
    /// Literal shape/dtype misuse.
    Literal(String),
    /// I/O while loading an HLO artifact.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(m) => write!(f, "{m}"),
            Error::Literal(m) => write!(f, "literal error: {m}"),
            Error::Io(e) => write!(f, "hlo artifact io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a literal can carry. Sealed to the two dtypes the
/// fedcompress artifacts use.
pub trait Element: Copy + 'static {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor value: element buffer + dimensions.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: Element>(v: T) -> Literal {
        Literal {
            data: T::wrap(vec![v]),
            dims: Vec::new(),
        }
    }

    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal {
            data: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
        }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::Literal(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error::Literal("dtype mismatch in to_vec".to_string()))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Split a tuple literal into its components.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.data {
            Data::Tuple(v) => Ok(std::mem::take(v)),
            _ => Err(Error::Literal("not a tuple literal".to_string())),
        }
    }
}

/// Parsed HLO module (text is retained verbatim; the stub never
/// compiles it).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path).map_err(Error::Io)?;
        Ok(HloModuleProto { _text: text })
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Stand up the CPU PJRT client. Always fails in the stub build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable(STUB_MSG))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable(STUB_MSG))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable(STUB_MSG))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_has_no_dims() {
        let l = Literal::scalar(7i32);
        assert!(l.dims().is_empty());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn runtime_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
